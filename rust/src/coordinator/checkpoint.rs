//! Checkpointing + event-sourced round log.
//!
//! Two artifacts live in `checkpoint.dir`:
//!
//! * **Snapshots** (`snapshot_r{N:06}.ckpt`) — a versioned, self-describing
//!   binary capture of every piece of cross-round driver state after `N`
//!   completed rounds: the global model, the aggregator's momentum/buffer
//!   state, the async late-update buffer with staleness tags, the lazy-pool
//!   roster (with per-client suspended batch-cursor draw counts), the
//!   shipped-decoder set, and the traffic-ledger totals. Everything *not*
//!   in a snapshot is a pure function of `(config, seed)` and is rebuilt
//!   bit-identically on resume — see ARCHITECTURE.md §Checkpointing &
//!   replay for the argument.
//! * **Event log** (`events.log`) — a compact append-only record per
//!   round: the selected set, admission fates, eval results and byte
//!   counts. One record is appended after every round; the reader
//!   tolerates a torn trailing record, and resume truncates records at or
//!   after the resume round so a crash between the event append and the
//!   snapshot write (in either order) repairs to the uninterrupted log.
//!
//! The byte dialect is [`crate::util::codec`]: little-endian integers,
//! floats as raw bit patterns, length-prefixed strings. Snapshots carry a
//! magic, a format version and an FNV-1a content hash; corrupt, truncated
//! or version-skewed files are rejected with typed
//! [`FedAeError::Checkpoint`] errors, never panics.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::compression::CompressedUpdate;
use crate::config::{CheckpointConfig, ExperimentConfig};
use crate::coordinator::{BufferedUpdate, StragglerStats};
use crate::error::{FedAeError, Result};
use crate::network::{Direction, LedgerTotals, TrafficKind};
use crate::util::codec::{self, Reader};

/// Magic prefix of a snapshot file.
pub const SNAPSHOT_MAGIC: [u8; 8] = *b"FAECKPT1";
/// Snapshot format version this build reads and writes.
pub const SNAPSHOT_VERSION: u32 = 1;
/// Magic prefix of the event-log file.
pub const EVENTS_MAGIC: [u8; 8] = *b"FAEEVTL1";

/// File name of the snapshot taken after `completed` rounds.
pub fn snapshot_file_name(completed: usize) -> String {
    format!("snapshot_r{completed:06}.ckpt")
}

/// The event-log path under a checkpoint directory.
pub fn events_path(dir: &Path) -> PathBuf {
    dir.join("events.log")
}

/// The newest snapshot in a checkpoint directory, if any (file names are
/// zero-padded, so lexicographic max is numeric max).
pub fn latest_snapshot(dir: &Path) -> Result<Option<PathBuf>> {
    let mut best: Option<PathBuf> = None;
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        if name.starts_with("snapshot_r") && name.ends_with(".ckpt") {
            if best
                .as_ref()
                .and_then(|b| b.file_name())
                .and_then(|n| n.to_str())
                .map_or(true, |b| name > b)
            {
                best = Some(path);
            }
        }
    }
    Ok(best)
}

fn direction_tag(d: Direction) -> u8 {
    match d {
        Direction::Up => 0,
        Direction::Down => 1,
    }
}

fn direction_from(tag: u8) -> Result<Direction> {
    match tag {
        0 => Ok(Direction::Up),
        1 => Ok(Direction::Down),
        other => Err(FedAeError::Checkpoint(format!(
            "unknown direction tag {other}"
        ))),
    }
}

fn kind_tag(k: TrafficKind) -> u8 {
    match k {
        TrafficKind::Update => 0,
        TrafficKind::GlobalModel => 1,
        TrafficKind::DecoderShipment => 2,
        TrafficKind::Control => 3,
    }
}

fn kind_from(tag: u8) -> Result<TrafficKind> {
    match tag {
        0 => Ok(TrafficKind::Update),
        1 => Ok(TrafficKind::GlobalModel),
        2 => Ok(TrafficKind::DecoderShipment),
        3 => Ok(TrafficKind::Control),
        other => Err(FedAeError::Checkpoint(format!(
            "unknown traffic-kind tag {other}"
        ))),
    }
}

/// The config fingerprint a snapshot carries so `--resume` can refuse a
/// run whose config silently changed: same seed, model manifest entry,
/// topology, compression scheme, aggregation algorithm, engine mode and
/// selection policy — the inputs the rebuilt (non-snapshotted) state is a
/// pure function of.
#[derive(Debug, Clone, PartialEq)]
pub struct CompatBlock {
    /// Experiment seed (every derived stream keys off it).
    pub seed: u64,
    /// Model manifest entry name.
    pub model: String,
    /// Model parameter count from the manifest.
    pub n_params: u64,
    /// Registered population size (`fl.collaborators`).
    pub collaborators: u64,
    /// Compression scheme, parameters included (`Debug` rendering).
    pub compression: String,
    /// Aggregation algorithm, parameters included (`Debug` rendering).
    pub aggregation: String,
    /// Engine mode name (`sync` / `async`).
    pub engine_mode: String,
    /// Client-selection policy name.
    pub selection_policy: String,
}

impl CompatBlock {
    /// The fingerprint of a live config.
    pub fn of(cfg: &ExperimentConfig, n_params: usize) -> CompatBlock {
        CompatBlock {
            seed: cfg.seed,
            model: cfg.model.clone(),
            n_params: n_params as u64,
            collaborators: cfg.fl.collaborators as u64,
            compression: format!("{:?}", cfg.compression),
            aggregation: format!("{:?}", cfg.aggregation),
            engine_mode: cfg.engine.mode.name().to_string(),
            selection_policy: cfg.selection.policy.name().to_string(),
        }
    }

    /// Reject a resume into an incompatible config, naming the first
    /// mismatched field.
    pub fn check(&self, cfg: &ExperimentConfig, n_params: usize) -> Result<()> {
        let live = CompatBlock::of(cfg, n_params);
        let pairs = [
            ("seed", self.seed.to_string(), live.seed.to_string()),
            ("model", self.model.clone(), live.model.clone()),
            ("n_params", self.n_params.to_string(), live.n_params.to_string()),
            (
                "fl.collaborators",
                self.collaborators.to_string(),
                live.collaborators.to_string(),
            ),
            ("compression", self.compression.clone(), live.compression.clone()),
            ("aggregation", self.aggregation.clone(), live.aggregation.clone()),
            ("engine.mode", self.engine_mode.clone(), live.engine_mode.clone()),
            (
                "selection.policy",
                self.selection_policy.clone(),
                live.selection_policy.clone(),
            ),
        ];
        for (field, snap, cur) in pairs {
            if snap != cur {
                return Err(FedAeError::Checkpoint(format!(
                    "--resume config mismatch: snapshot was taken with {field} = `{snap}`, \
                     this config has `{cur}`"
                )));
            }
        }
        Ok(())
    }

    fn write(&self, buf: &mut Vec<u8>) {
        codec::put_u64(buf, self.seed);
        codec::put_str(buf, &self.model);
        codec::put_u64(buf, self.n_params);
        codec::put_u64(buf, self.collaborators);
        codec::put_str(buf, &self.compression);
        codec::put_str(buf, &self.aggregation);
        codec::put_str(buf, &self.engine_mode);
        codec::put_str(buf, &self.selection_policy);
    }

    fn read(r: &mut Reader<'_>) -> Result<CompatBlock> {
        Ok(CompatBlock {
            seed: r.u64()?,
            model: r.str()?,
            n_params: r.u64()?,
            collaborators: r.u64()?,
            compression: r.str()?,
            aggregation: r.str()?,
            engine_mode: r.str()?,
            selection_policy: r.str()?,
        })
    }
}

/// One resident client's snapshot entry.
#[derive(Debug, Clone, PartialEq)]
pub struct RosterEntry {
    /// Client id.
    pub id: usize,
    /// Round this client was last selected (the LRU eviction key).
    pub last_used: usize,
    /// Batches its seeded batch iterator has drawn so far; resume
    /// fast-forwards the rebuilt iterator to exactly here.
    pub batches_drawn: u64,
}

/// Async-engine state captured in a snapshot: the late-update buffer
/// (with origin/apply rounds, i.e. staleness tags) and the cumulative
/// straggler totals.
#[derive(Debug, Clone)]
pub struct AsyncState {
    /// Buffered late updates not yet applied.
    pub pending: Vec<BufferedUpdate>,
    /// Cumulative admission accounting.
    pub totals: StragglerStats,
}

/// A versioned capture of every piece of cross-round driver state.
///
/// Serialization is self-describing: magic, format version, payload
/// length and an FNV-1a content hash precede the payload, so
/// [`Snapshot::from_bytes`] rejects foreign files, version skew,
/// truncation and corruption with typed errors.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Config fingerprint checked on `--resume`.
    pub compat: CompatBlock,
    /// Rounds completed when the snapshot was taken (= the next round to
    /// run on resume).
    pub round: usize,
    /// The global model parameters.
    pub global: Vec<f32>,
    /// The server aggregator's exported state
    /// ([`crate::aggregation::Aggregator::export_state`]); empty for
    /// stateless aggregators.
    pub agg_state: Vec<u8>,
    /// Async-engine state; `None` in sync mode.
    pub async_state: Option<AsyncState>,
    /// Resident clients (the lazy pool).
    pub roster: Vec<RosterEntry>,
    /// Evicted clients' suspended batch-cursor draw counts, as
    /// `(id, batches_drawn)`.
    pub suspended: Vec<(usize, u64)>,
    /// Clients whose decoder shipment was already metered.
    pub shipped: Vec<usize>,
    /// Traffic-ledger totals (restored as the new ledger baseline).
    pub ledger: LedgerTotals,
}

impl Snapshot {
    /// Serialize: header (magic, version, payload length, content hash)
    /// followed by the payload.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut p = Vec::new();
        self.compat.write(&mut p);
        codec::put_u64(&mut p, self.round as u64);
        codec::put_vec_f32(&mut p, &self.global);
        codec::put_bytes(&mut p, &self.agg_state);
        match &self.async_state {
            None => codec::put_u8(&mut p, 0),
            Some(a) => {
                codec::put_u8(&mut p, 1);
                codec::put_u64(&mut p, a.pending.len() as u64);
                for b in &a.pending {
                    codec::put_u64(&mut p, b.collaborator as u64);
                    codec::put_u32(&mut p, b.n_samples);
                    codec::put_u64(&mut p, b.origin_round as u64);
                    codec::put_u64(&mut p, b.apply_round as u64);
                    codec::put_bytes(&mut p, &b.update.to_bytes());
                }
                codec::put_u64(&mut p, a.totals.admitted as u64);
                codec::put_u64(&mut p, a.totals.late as u64);
                codec::put_u64(&mut p, a.totals.dropped as u64);
                codec::put_u64(&mut p, a.totals.stale_applied as u64);
                codec::put_u64(&mut p, a.totals.max_staleness as u64);
                codec::put_f64(&mut p, a.totals.sim_round_seconds);
            }
        }
        codec::put_u64(&mut p, self.roster.len() as u64);
        for e in &self.roster {
            codec::put_u64(&mut p, e.id as u64);
            codec::put_u64(&mut p, e.last_used as u64);
            codec::put_u64(&mut p, e.batches_drawn);
        }
        codec::put_u64(&mut p, self.suspended.len() as u64);
        for (id, drawn) in &self.suspended {
            codec::put_u64(&mut p, *id as u64);
            codec::put_u64(&mut p, *drawn);
        }
        codec::put_u64(&mut p, self.shipped.len() as u64);
        for id in &self.shipped {
            codec::put_u64(&mut p, *id as u64);
        }
        codec::put_u64(&mut p, self.ledger.by_kind.len() as u64);
        for (d, k, bytes) in &self.ledger.by_kind {
            codec::put_u8(&mut p, direction_tag(*d));
            codec::put_u8(&mut p, kind_tag(*k));
            codec::put_u64(&mut p, *bytes);
        }
        codec::put_u64(&mut p, self.ledger.total_bytes);
        codec::put_f64(&mut p, self.ledger.total_sim_seconds);
        codec::put_u64(&mut p, self.ledger.update_up_count);

        let mut out = Vec::with_capacity(28 + p.len());
        out.extend_from_slice(&SNAPSHOT_MAGIC);
        codec::put_u32(&mut out, SNAPSHOT_VERSION);
        codec::put_u64(&mut out, p.len() as u64);
        codec::put_u64(&mut out, codec::fnv1a64(&p));
        out.extend_from_slice(&p);
        out
    }

    /// Parse and verify a serialized snapshot.
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot> {
        if bytes.len() < 28 {
            return Err(FedAeError::Checkpoint(format!(
                "snapshot too short: {} bytes, header is 28",
                bytes.len()
            )));
        }
        if bytes[..8] != SNAPSHOT_MAGIC {
            return Err(FedAeError::Checkpoint(
                "not a fedae snapshot (bad magic)".into(),
            ));
        }
        let mut h = Reader::new(&bytes[8..28]);
        let version = h.u32()?;
        if version != SNAPSHOT_VERSION {
            return Err(FedAeError::Checkpoint(format!(
                "snapshot format version {version} unsupported (this build reads \
                 version {SNAPSHOT_VERSION})"
            )));
        }
        let payload_len = h.u64()? as usize;
        let hash = h.u64()?;
        let payload = &bytes[28..];
        if payload.len() != payload_len {
            return Err(FedAeError::Checkpoint(format!(
                "snapshot payload is {} bytes, header declares {payload_len}",
                payload.len()
            )));
        }
        if codec::fnv1a64(payload) != hash {
            return Err(FedAeError::Checkpoint(
                "snapshot content hash mismatch: file is corrupt".into(),
            ));
        }

        let mut r = Reader::new(payload);
        let compat = CompatBlock::read(&mut r)?;
        let round = r.u64()? as usize;
        let global = r.vec_f32()?;
        let agg_state = r.bytes()?.to_vec();
        let async_state = match r.u8()? {
            0 => None,
            1 => {
                let n = r.len_prefix()?;
                let mut pending = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    let collaborator = r.u64()? as usize;
                    let n_samples = r.u32()?;
                    let origin_round = r.u64()? as usize;
                    let apply_round = r.u64()? as usize;
                    let update = CompressedUpdate::from_bytes(r.bytes()?)?;
                    pending.push(BufferedUpdate {
                        collaborator,
                        n_samples,
                        update,
                        origin_round,
                        apply_round,
                    });
                }
                let totals = StragglerStats {
                    admitted: r.u64()? as usize,
                    late: r.u64()? as usize,
                    dropped: r.u64()? as usize,
                    stale_applied: r.u64()? as usize,
                    max_staleness: r.u64()? as usize,
                    sim_round_seconds: r.f64()?,
                };
                Some(AsyncState { pending, totals })
            }
            other => {
                return Err(FedAeError::Checkpoint(format!(
                    "unknown async-state flag {other}"
                )))
            }
        };
        let n = r.len_prefix()?;
        let mut roster = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            roster.push(RosterEntry {
                id: r.u64()? as usize,
                last_used: r.u64()? as usize,
                batches_drawn: r.u64()?,
            });
        }
        let n = r.len_prefix()?;
        let mut suspended = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            suspended.push((r.u64()? as usize, r.u64()?));
        }
        let n = r.len_prefix()?;
        let mut shipped = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            shipped.push(r.u64()? as usize);
        }
        let n = r.len_prefix()?;
        let mut by_kind = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let d = direction_from(r.u8()?)?;
            let k = kind_from(r.u8()?)?;
            by_kind.push((d, k, r.u64()?));
        }
        let ledger = LedgerTotals {
            by_kind,
            total_bytes: r.u64()?,
            total_sim_seconds: r.f64()?,
            update_up_count: r.u64()?,
        };
        r.finish()?;
        Ok(Snapshot {
            compat,
            round,
            global,
            agg_state,
            async_state,
            roster,
            suspended,
            shipped,
            ledger,
        })
    }

    /// Write atomically (temp file + rename), so a torn write never
    /// clobbers an existing good snapshot.
    pub fn write_to(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("ckpt.tmp");
        fs::write(&tmp, self.to_bytes())?;
        fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read and verify a snapshot file.
    pub fn read_from(path: &Path) -> Result<Snapshot> {
        Snapshot::from_bytes(&fs::read(path)?)
    }
}

/// One round's event-log record: what happened, to whom, at what cost.
#[derive(Debug, Clone)]
pub struct EventRecord {
    /// The round this record describes.
    pub round: usize,
    /// The sampled id set (sorted; includes async over-provision slack).
    pub selected: Vec<usize>,
    /// Fresh updates admitted this round.
    pub admitted: usize,
    /// Uploads past the deadline, buffered for a later round.
    pub late: usize,
    /// Uploads dropped outright.
    pub dropped: usize,
    /// Buffered stale updates applied this round.
    pub stale_applied: usize,
    /// On-time arrivals discarded by over-provisioned admission.
    pub discarded: usize,
    /// Post-aggregation global eval loss.
    pub eval_loss: f32,
    /// Post-aggregation global eval accuracy.
    pub eval_acc: f32,
    /// Mean reconstruction MSE (NaN when no fresh update applied).
    pub mean_recon_mse: f32,
    /// Uplink bytes this round.
    pub bytes_up: u64,
    /// Downlink bytes this round.
    pub bytes_down: u64,
    /// Full-vector decodes during aggregation.
    pub full_decodes: u64,
    /// Range decodes during aggregation.
    pub range_decodes: u64,
}

impl PartialEq for EventRecord {
    fn eq(&self, other: &EventRecord) -> bool {
        self.round == other.round
            && self.selected == other.selected
            && self.admitted == other.admitted
            && self.late == other.late
            && self.dropped == other.dropped
            && self.stale_applied == other.stale_applied
            && self.discarded == other.discarded
            && self.eval_loss.to_bits() == other.eval_loss.to_bits()
            && self.eval_acc.to_bits() == other.eval_acc.to_bits()
            && self.mean_recon_mse.to_bits() == other.mean_recon_mse.to_bits()
            && self.bytes_up == other.bytes_up
            && self.bytes_down == other.bytes_down
            && self.full_decodes == other.full_decodes
            && self.range_decodes == other.range_decodes
    }
}

impl EventRecord {
    fn body(&self) -> Vec<u8> {
        let mut b = Vec::new();
        codec::put_u64(&mut b, self.round as u64);
        codec::put_u64(&mut b, self.selected.len() as u64);
        for id in &self.selected {
            codec::put_u64(&mut b, *id as u64);
        }
        codec::put_u64(&mut b, self.admitted as u64);
        codec::put_u64(&mut b, self.late as u64);
        codec::put_u64(&mut b, self.dropped as u64);
        codec::put_u64(&mut b, self.stale_applied as u64);
        codec::put_u64(&mut b, self.discarded as u64);
        codec::put_f32(&mut b, self.eval_loss);
        codec::put_f32(&mut b, self.eval_acc);
        codec::put_f32(&mut b, self.mean_recon_mse);
        codec::put_u64(&mut b, self.bytes_up);
        codec::put_u64(&mut b, self.bytes_down);
        codec::put_u64(&mut b, self.full_decodes);
        codec::put_u64(&mut b, self.range_decodes);
        b
    }

    fn parse(body: &[u8]) -> Result<EventRecord> {
        let mut r = Reader::new(body);
        let round = r.u64()? as usize;
        let n = r.len_prefix()?;
        let mut selected = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            selected.push(r.u64()? as usize);
        }
        let rec = EventRecord {
            round,
            selected,
            admitted: r.u64()? as usize,
            late: r.u64()? as usize,
            dropped: r.u64()? as usize,
            stale_applied: r.u64()? as usize,
            discarded: r.u64()? as usize,
            eval_loss: r.f32()?,
            eval_acc: r.f32()?,
            mean_recon_mse: r.f32()?,
            bytes_up: r.u64()?,
            bytes_down: r.u64()?,
            full_decodes: r.u64()?,
            range_decodes: r.u64()?,
        };
        r.finish()?;
        Ok(rec)
    }
}

/// Append one record to the event log, creating the file (with its
/// magic) on first use. The record is written as a single length-prefixed
/// blob so a crash mid-write leaves a detectable torn tail, not a
/// corrupted log.
pub fn append_event(dir: &Path, rec: &EventRecord) -> Result<()> {
    let path = events_path(dir);
    let mut file = fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)?;
    let mut buf = Vec::new();
    if file.metadata()?.len() == 0 {
        buf.extend_from_slice(&EVENTS_MAGIC);
    }
    codec::put_bytes(&mut buf, &rec.body());
    file.write_all(&buf)?;
    Ok(())
}

/// Read every intact record in the event log. A missing file reads as
/// empty; a torn trailing record (crash mid-append) is silently dropped;
/// corruption anywhere else is a typed error.
pub fn read_events(dir: &Path) -> Result<Vec<EventRecord>> {
    let path = events_path(dir);
    let bytes = match fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e.into()),
    };
    if bytes.len() < 8 || bytes[..8] != EVENTS_MAGIC {
        return Err(FedAeError::Checkpoint(
            "not a fedae event log (bad magic)".into(),
        ));
    }
    let mut r = Reader::new(&bytes[8..]);
    let mut out = Vec::new();
    while r.remaining() > 0 {
        // Length prefix or declared body extending past EOF: torn tail.
        if r.remaining() < 8 {
            break;
        }
        match r.bytes() {
            Ok(body) => out.push(EventRecord::parse(body)?),
            Err(_) => break,
        }
    }
    Ok(out)
}

/// Drop every record for `round` or later, rewriting the log in place.
/// Called on resume so rounds replayed after the snapshot append exactly
/// one record each — the repaired log is byte-identical to an
/// uninterrupted run's.
pub fn truncate_events_from(dir: &Path, round: usize) -> Result<()> {
    let keep: Vec<EventRecord> = read_events(dir)?
        .into_iter()
        .filter(|rec| rec.round < round)
        .collect();
    let mut buf = Vec::from(EVENTS_MAGIC);
    for rec in &keep {
        codec::put_bytes(&mut buf, &rec.body());
    }
    fs::write(events_path(dir), buf)?;
    Ok(())
}

/// The driver's checkpoint writer: owns the directory, the snapshot
/// cadence (`checkpoint.every_rounds`) and retention
/// (`checkpoint.keep_last`, 0 = keep all).
#[derive(Debug)]
pub struct Checkpointer {
    dir: PathBuf,
    every_rounds: usize,
    keep_last: usize,
}

impl Checkpointer {
    /// Create the checkpoint directory and the writer.
    pub fn new(cfg: &CheckpointConfig) -> Result<Checkpointer> {
        let dir = PathBuf::from(&cfg.dir);
        fs::create_dir_all(&dir)?;
        Ok(Checkpointer {
            dir,
            every_rounds: cfg.every_rounds,
            keep_last: cfg.keep_last,
        })
    }

    /// The checkpoint directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append one round's event record.
    pub fn record_round(&self, rec: &EventRecord) -> Result<()> {
        append_event(&self.dir, rec)
    }

    /// Whether a snapshot is due after `completed` rounds.
    pub fn snapshot_due(&self, completed: usize) -> bool {
        completed > 0 && completed % self.every_rounds == 0
    }

    /// Write a snapshot (atomic temp + rename), prune old ones, and
    /// return its path.
    pub fn write_snapshot(&self, snap: &Snapshot) -> Result<PathBuf> {
        let path = self.dir.join(snapshot_file_name(snap.round));
        snap.write_to(&path)?;
        self.prune()?;
        Ok(path)
    }

    /// Truncate the event log at the resume round.
    pub fn truncate_events_from(&self, round: usize) -> Result<()> {
        truncate_events_from(&self.dir, round)
    }

    /// Remove the oldest snapshots beyond `keep_last` (no-op when 0).
    fn prune(&self) -> Result<()> {
        if self.keep_last == 0 {
            return Ok(());
        }
        let mut names: Vec<PathBuf> = fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("snapshot_r") && n.ends_with(".ckpt"))
                    .unwrap_or(false)
            })
            .collect();
        names.sort();
        while names.len() > self.keep_last {
            fs::remove_file(names.remove(0))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fedae_ckpt_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_snapshot() -> Snapshot {
        Snapshot {
            compat: CompatBlock {
                seed: 7,
                model: "mnist".into(),
                n_params: 101_770,
                collaborators: 4,
                compression: "Identity".into(),
                aggregation: "FedAvgM { beta: 0.9 }".into(),
                engine_mode: "async".into(),
                selection_policy: "uniform".into(),
            },
            round: 3,
            global: vec![0.5, -0.0, f32::NAN, 2.25],
            agg_state: vec![1, 2, 3, 4],
            async_state: Some(AsyncState {
                pending: vec![BufferedUpdate {
                    collaborator: 2,
                    n_samples: 64,
                    update: CompressedUpdate::Raw {
                        values: vec![1.0, -2.0],
                    },
                    origin_round: 1,
                    apply_round: 4,
                }],
                totals: StragglerStats {
                    admitted: 5,
                    late: 2,
                    dropped: 1,
                    stale_applied: 1,
                    max_staleness: 3,
                    sim_round_seconds: 12.5,
                },
            }),
            roster: vec![
                RosterEntry {
                    id: 0,
                    last_used: 2,
                    batches_drawn: 40,
                },
                RosterEntry {
                    id: 3,
                    last_used: 3,
                    batches_drawn: 12,
                },
            ],
            suspended: vec![(1, 99)],
            shipped: vec![0, 1, 3],
            ledger: LedgerTotals {
                by_kind: vec![
                    (Direction::Up, TrafficKind::Update, 4096),
                    (Direction::Down, TrafficKind::GlobalModel, 8192),
                ],
                total_bytes: 12288,
                total_sim_seconds: 3.75,
                update_up_count: 9,
            },
        }
    }

    #[test]
    fn snapshot_round_trips_byte_identically() {
        let snap = sample_snapshot();
        let bytes = snap.to_bytes();
        let back = Snapshot::from_bytes(&bytes).unwrap();
        // Serialize → parse → serialize is byte-identical (NaN global
        // params included, since floats travel as bit patterns).
        assert_eq!(back.to_bytes(), bytes);
        assert_eq!(back.compat, snap.compat);
        assert_eq!(back.round, snap.round);
        assert_eq!(
            back.global.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            snap.global.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.agg_state, snap.agg_state);
        assert_eq!(back.roster, snap.roster);
        assert_eq!(back.suspended, snap.suspended);
        assert_eq!(back.shipped, snap.shipped);
        assert_eq!(back.ledger, snap.ledger);
        let a = back.async_state.unwrap();
        let b = snap.async_state.unwrap();
        assert_eq!(a.totals, b.totals);
        assert_eq!(a.pending.len(), 1);
        assert_eq!(a.pending[0].collaborator, b.pending[0].collaborator);
        assert_eq!(a.pending[0].update, b.pending[0].update);
    }

    #[test]
    fn snapshot_rejects_corruption_with_typed_errors() {
        let bytes = sample_snapshot().to_bytes();

        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        let err = Snapshot::from_bytes(&bad).unwrap_err();
        assert!(matches!(err, FedAeError::Checkpoint(_)));
        assert!(err.to_string().contains("magic"));

        // Version skew.
        let mut bad = bytes.clone();
        bad[8] = 99;
        let err = Snapshot::from_bytes(&bad).unwrap_err();
        assert!(matches!(err, FedAeError::Checkpoint(_)));
        assert!(err.to_string().contains("version 99"));

        // Payload bit flip breaks the content hash.
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        let err = Snapshot::from_bytes(&bad).unwrap_err();
        assert!(matches!(err, FedAeError::Checkpoint(_)));
        assert!(err.to_string().contains("hash"));

        // Truncation.
        let err = Snapshot::from_bytes(&bytes[..bytes.len() - 3]).unwrap_err();
        assert!(matches!(err, FedAeError::Checkpoint(_)));

        // Too short to even hold a header.
        assert!(Snapshot::from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn compat_check_names_the_mismatched_field() {
        use crate::config::manifest;
        use crate::util::json::Json;
        let mjson = Json::parse(&manifest::tests::test_manifest_json()).unwrap();
        let m = manifest::Manifest::from_json(&mjson).unwrap();
        let n_params = m.model("toy").unwrap().n_params;
        let mut cfg = ExperimentConfig::default();
        cfg.model = "toy".into();
        cfg.compression = crate::config::CompressionConfig::Identity;
        let block = CompatBlock::of(&cfg, n_params);
        block.check(&cfg, n_params).unwrap();

        let mut other = cfg.clone();
        other.seed = cfg.seed.wrapping_add(1);
        let err = block.check(&other, n_params).unwrap_err();
        assert!(err.to_string().contains("seed"));

        let mut other = cfg.clone();
        other.compression = crate::config::CompressionConfig::Subsample { fraction: 0.5 };
        let err = block.check(&other, n_params).unwrap_err();
        assert!(err.to_string().contains("compression"));
    }

    #[test]
    fn event_log_appends_reads_and_truncates() {
        let dir = test_dir("events");
        let rec = |round: usize| EventRecord {
            round,
            selected: vec![0, round],
            admitted: 2,
            late: 0,
            dropped: 0,
            stale_applied: 0,
            discarded: 0,
            eval_loss: 0.5,
            eval_acc: 0.9,
            mean_recon_mse: f32::NAN,
            bytes_up: 100,
            bytes_down: 200,
            full_decodes: 2,
            range_decodes: 0,
        };
        for round in 0..5 {
            append_event(&dir, &rec(round)).unwrap();
        }
        let all = read_events(&dir).unwrap();
        assert_eq!(all.len(), 5);
        // NaN recon MSE still compares equal (bitwise).
        assert_eq!(all[3], rec(3));

        truncate_events_from(&dir, 3).unwrap();
        let kept = read_events(&dir).unwrap();
        assert_eq!(kept.len(), 3);
        assert_eq!(kept.last().unwrap().round, 2);
        // Appending after truncation continues the log seamlessly.
        append_event(&dir, &rec(3)).unwrap();
        assert_eq!(read_events(&dir).unwrap().len(), 4);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn event_log_tolerates_torn_tail() {
        let dir = test_dir("torn");
        let rec = EventRecord {
            round: 0,
            selected: vec![1],
            admitted: 1,
            late: 0,
            dropped: 0,
            stale_applied: 0,
            discarded: 0,
            eval_loss: 1.0,
            eval_acc: 0.5,
            mean_recon_mse: 0.0,
            bytes_up: 10,
            bytes_down: 20,
            full_decodes: 1,
            range_decodes: 0,
        };
        append_event(&dir, &rec).unwrap();
        append_event(&dir, &rec).unwrap();
        // Simulate a crash mid-append: chop the second record in half.
        let path = events_path(&dir);
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let survivors = read_events(&dir).unwrap();
        assert_eq!(survivors.len(), 1);
        assert_eq!(survivors[0], rec);
        // A foreign file is rejected outright.
        fs::write(&path, b"not an event log at all").unwrap();
        assert!(read_events(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpointer_cadence_prune_and_latest() {
        let dir = test_dir("cadence");
        let cfg = CheckpointConfig {
            dir: dir.to_string_lossy().into_owned(),
            every_rounds: 2,
            keep_last: 2,
        };
        let ck = Checkpointer::new(&cfg).unwrap();
        assert!(!ck.snapshot_due(0));
        assert!(!ck.snapshot_due(1));
        assert!(ck.snapshot_due(2));
        assert!(ck.snapshot_due(4));

        let mut snap = sample_snapshot();
        for completed in [2usize, 4, 6] {
            snap.round = completed;
            ck.write_snapshot(&snap).unwrap();
        }
        // keep_last = 2: the round-2 snapshot was pruned.
        assert!(!dir.join(snapshot_file_name(2)).exists());
        assert!(dir.join(snapshot_file_name(4)).exists());
        let latest = latest_snapshot(&dir).unwrap().unwrap();
        assert_eq!(latest, dir.join(snapshot_file_name(6)));
        assert_eq!(Snapshot::read_from(&latest).unwrap().round, 6);
        fs::remove_dir_all(&dir).unwrap();
    }
}
