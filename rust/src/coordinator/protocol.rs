//! Message-driven coordinator protocol: the explicit state machine that
//! turns the in-process simulator into a multi-process federation.
//!
//! # State machine
//!
//! ```text
//!            Hello (version/id checked)          all rounds done
//! Standby ──────────────────────────▶ Round(0) ─▶ … ─▶ Round(R-1) ──▶ Finished
//!    │ ▲ rendezvous until                 │ per round:                    │
//!    │ │ `protocol.min_participants`      │  RoundStart → shipments →     │ Shutdown
//!    │ │ workers joined                   │  GlobalModel → updates +      │ to every
//!    ▼ │                                  │  eval reports → RoundEnd      ▼ worker
//!  (timeout ⇒ error)                      ▼  (silent workers evicted,
//!      │                                     Rejoin ⇒ CatchUp re-entry)
//!      └── quorum stall: fewer than `protocol.quorum` updates survive
//!          ⇒ back to Standby, re-rendezvous, retry the same round
//!          (bounded; repeated stalls are an error, never a deadlock)
//! ```
//!
//! The coordinator ([`ProtocolServer`]) drives rounds purely by
//! exchanging [`Message`] frames over a [`Transport`], so the same loop
//! runs over deterministic in-process channels
//! ([`crate::transport::InProcChannel`]) and real TCP sockets
//! ([`crate::transport::TcpTransport`]) — `fedae serve` / `fedae worker`
//! are thin wrappers over [`ProtocolServer::run`] and [`run_worker`].
//!
//! # Bitwise parity with the simulator
//!
//! A protocol federation on config `C` produces the *same bits* as
//! [`super::FlDriver`] on `C` — final global params, per-round
//! [`RoundOutcome`]s, and [`LedgerTotals`] — because every seeded
//! stream and every float operation is replicated exactly:
//!
//! * selection draws from `seed ^ SELECTION_SEED_TAG` via the identical
//!   [`ClientSelector`] construction;
//! * each worker rebuilds its collaborator as the same pure function of
//!   `(seed, id)` the simulator uses for lazy activation (shard, AE
//!   pre-pass seeded `seed + id`, non-AE compressor seeded
//!   `seed*31 + id`, training stream seeded `seed + 1000 + id`);
//! * updates are decoded server-side and aggregated batch-materialized
//!   in collaborator-id order — bitwise-equal to the simulator's
//!   streaming path (pinned by `rust/tests/streaming_agg.rs`);
//! * reconstruction MSE is computed on the *worker* against its own
//!   post-training params and reported via [`Message::EvalReport`]:
//!   decompression is stateless for every scheme, so the worker-side
//!   value is bit-identical to the simulator's server-side one;
//! * byte metering is frame-exact: the worker sends the very frames the
//!   simulator costs ([`Message::encoded_update`] /
//!   [`Message::decoder_shipment`] are the shared construction path),
//!   and control frames (`Hello`, `Heartbeat`, `RoundStart`,
//!   `RoundEnd`, `Reject`, `EvalReport`, `Shutdown`) are never metered
//!   in either world.
//!
//! `rust/tests/protocol.rs` asserts all three parity surfaces over
//! loopback TCP and in-proc channels, plus the fault matrix below.
//!
//! # Faults
//!
//! * A worker whose connection errors repeatedly
//!   ([`RECV_ERROR_TOLERANCE`] consecutive receive errors; a single
//!   transient error is tolerated), or that stays silent past
//!   `protocol.heartbeat_ms` (before acking the round) /
//!   `protocol.round_timeout_ms` (after acking — it is presumed
//!   computing), is evicted: [`super::RoundState::evict`] removes it
//!   from the barrier and the round completes without it. A dropped
//!   connection gets `protocol.rejoin_grace_ms` before silence-eviction
//!   kicks in, giving the worker a window to [`Message::Rejoin`].
//! * `EncodedUpdate` / `DecoderShipment` frames carry an FNV-1a content
//!   hash: mismatches are answered with
//!   [`RejectReason::HashMismatch`] and ignored; byte-identical replays
//!   are deduplicated (counted, never re-metered, never re-aggregated).
//! * A `Hello` with the wrong protocol version, an out-of-range id, or
//!   an id that is already live is answered with a typed
//!   [`Message::Reject`] and the connection dropped — a *dead* slot
//!   with the same id is replaced instead (reconnect).
//!
//! # Recovery plane (protocol v3)
//!
//! A worker that lost its connection redials and opens with
//! [`Message::Rejoin`] (see
//! [`crate::transport::retry::ReconnectingTransport`]). The coordinator
//! answers with one [`Message::CatchUp`] carrying the current round,
//! whether the worker's one-time decoder shipment is still needed, and —
//! only when the worker is an active participant of an in-flight
//! broadcast whose update has not arrived — the current global params,
//! so it re-enters the round barrier. A `Rejoin` supersedes any
//! existing endpoint for that id: the worker is the authority on its
//! own connection having died.
//!
//! Recovery frames are never metered: the `GlobalModel` broadcast they
//! replace was already costed at send time, the decoder shipment is
//! metered once per collaborator on arrival, and resent data-plane
//! frames dedup by content hash — so a rejoin that lands before the
//! round barrier leaves params, outcomes, and [`LedgerTotals`] bitwise
//! identical to the fault-free run (`rust/tests/chaos.rs`).
//!
//! # Quorum degradation
//!
//! With `protocol.quorum > 0`, a round whose surviving updates fall
//! below the floor is *not* committed: nothing is aggregated, the state
//! machine returns to `Standby`, re-rendezvouses (bounded by
//! `round_timeout_ms`), and retries the same round — re-broadcasting to
//! the re-formed cohort (re-metered: retransmission is a real cost, so
//! stalled runs do not claim bitwise ledger parity). Workers resend
//! their cached frames instead of retraining, so the retried round's
//! math is unchanged. [`MAX_ROUND_STALLS`] consecutive stalls abort
//! with a typed error. Stalls are recorded in
//! [`ProtocolReport::quorum_stalls`].

use std::collections::{BTreeMap, BTreeSet};
use std::net::{SocketAddr, TcpListener, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::aggregation::{Aggregator, WeightedUpdate};
use crate::collaborator::{run_prepass, Collaborator};
use crate::compression::{ae::AeCompressor, CompressedUpdate, MeteredDecoder, UpdateCompressor};
use crate::config::{CompressionConfig, EngineMode, ExperimentConfig, SelectionPolicy, Sharding};
use crate::data::{Dataset, ShardFactory, SynthKind};
use crate::error::{FedAeError, Result};
use crate::network::{Direction, LedgerTotals, SimulatedNetwork, TrafficKind};
use crate::runtime::{AePipeline, EvalStep, Runtime};
use crate::tensor;
use crate::transport::{Message, RejectReason, TcpTransport, Transport, PROTOCOL_VERSION};

use super::selection::{
    ClientSelector, SelectionStats, StratifiedSelector, UniformSelector, WeightedSelector,
};
use super::{AggRoundStats, RoundOutcome, RoundState, StragglerStats, SELECTION_SEED_TAG};

/// Per-endpoint poll interval of the coordinator's single-threaded
/// event loop (every blocking wait is bounded by this).
const POLL: Duration = Duration::from_millis(5);

/// Consecutive receive errors on one endpoint before the coordinator
/// marks it dead — a single transient error (one malformed frame, one
/// hiccup) does not cost a worker its connection.
pub const RECV_ERROR_TOLERANCE: u32 = 3;

/// Consecutive below-quorum stalls of the *same* round before the
/// coordinator gives up with a typed error instead of re-rendezvousing
/// again (bounds the standby-retry loop).
pub const MAX_ROUND_STALLS: usize = 3;

/// The coordinator's explicit protocol state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoordinatorState {
    /// Rendezvous: waiting for `protocol.min_participants` workers.
    Standby,
    /// Driving communication round `n`.
    Round(usize),
    /// Every configured round completed; `Shutdown` sent to workers.
    Finished,
}

impl std::fmt::Display for CoordinatorState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorState::Standby => write!(f, "STANDBY"),
            CoordinatorState::Round(n) => write!(f, "ROUND({n})"),
            CoordinatorState::Finished => write!(f, "FINISHED"),
        }
    }
}

/// Source of freshly connected, pre-`Hello` endpoints for the
/// coordinator — polled throughout the run, so late joiners and
/// reconnecting workers are admitted mid-experiment.
pub trait EndpointSource {
    /// Poll for one new endpoint; `Ok(None)` when none is waiting.
    fn poll(&mut self) -> Result<Option<Box<dyn Transport>>>;
}

/// A fixed set of endpoints handed over up front (in-proc federations:
/// one [`crate::transport::InProcChannel`] server end per worker).
pub struct StaticEndpoints {
    endpoints: Vec<Box<dyn Transport>>,
}

impl StaticEndpoints {
    /// Wrap the server-side endpoints; they are yielded in order.
    pub fn new(endpoints: Vec<Box<dyn Transport>>) -> StaticEndpoints {
        let mut endpoints = endpoints;
        endpoints.reverse();
        StaticEndpoints { endpoints }
    }
}

impl EndpointSource for StaticEndpoints {
    fn poll(&mut self) -> Result<Option<Box<dyn Transport>>> {
        Ok(self.endpoints.pop())
    }
}

/// Endpoints arriving over an in-process channel — the in-proc analogue
/// of [`TcpAcceptor`] for reconnection tests: worker threads push
/// freshly dialled server ends mid-run, exactly like a redialled TCP
/// connection landing in the accept queue.
pub struct ChannelEndpoints {
    rx: std::sync::mpsc::Receiver<Box<dyn Transport>>,
}

impl ChannelEndpoints {
    /// A connected (dial sender, endpoint source) pair.
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> (std::sync::mpsc::Sender<Box<dyn Transport>>, ChannelEndpoints) {
        let (tx, rx) = std::sync::mpsc::channel();
        (tx, ChannelEndpoints { rx })
    }
}

impl EndpointSource for ChannelEndpoints {
    fn poll(&mut self) -> Result<Option<Box<dyn Transport>>> {
        // Disconnected just means no more dialers exist — not an error;
        // the coordinator keeps serving the endpoints it already has.
        Ok(self.rx.try_recv().ok())
    }
}

/// Reconnect-aware non-blocking TCP accept loop: every accepted stream
/// becomes a hardened [`TcpTransport`] (frame ceiling + write timeout)
/// awaiting its `Hello`.
pub struct TcpAcceptor {
    listener: TcpListener,
    max_frame: usize,
}

impl TcpAcceptor {
    /// Bind and switch the listener to non-blocking accepts. Accepted
    /// connections inherit `max_frame` as their frame-size ceiling.
    pub fn bind(addr: impl ToSocketAddrs, max_frame: usize) -> Result<TcpAcceptor> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(TcpAcceptor { listener, max_frame })
    }

    /// The bound address (port resolution for `127.0.0.1:0` binds).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }
}

impl EndpointSource for TcpAcceptor {
    fn poll(&mut self) -> Result<Option<Box<dyn Transport>>> {
        match self.listener.accept() {
            Ok((stream, _)) => {
                let mut t = TcpTransport::new(stream);
                t.set_max_frame(self.max_frame);
                t.set_write_timeout(Some(Duration::from_secs(30)))?;
                Ok(Some(Box::new(t)))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

/// What a completed protocol run hands back: the parity surfaces
/// (outcomes, final params, ledger totals) plus fault accounting.
#[derive(Debug, Clone)]
pub struct ProtocolReport {
    /// One [`RoundOutcome`] per completed round, in order.
    pub outcomes: Vec<RoundOutcome>,
    /// The final global model parameters.
    pub final_params: Vec<f32>,
    /// Aggregate traffic-ledger totals (byte-exact simulator parity).
    pub ledger_totals: LedgerTotals,
    /// `(round, collaborator)` pairs evicted for silence/disconnect.
    pub evictions: Vec<(usize, usize)>,
    /// Replayed frames deduplicated by content hash.
    pub dedup_hits: u64,
    /// Frames answered with a [`Message::Reject`] or dropped as
    /// protocol violations.
    pub rejected_frames: u64,
    /// Unmetered control frames received (heartbeats, eval reports).
    pub control_frames: u64,
    /// Successful [`Message::Rejoin`] re-admissions (each answered with
    /// one unmetered [`Message::CatchUp`]).
    pub rejoins: u64,
    /// Worker connections that died on the coordinator side (transport
    /// errors past [`RECV_ERROR_TOLERANCE`], or send failures).
    pub conn_drops: u64,
    /// `(round, surviving_updates)` for every below-quorum stall that
    /// sent the coordinator back to STANDBY rendezvous.
    pub quorum_stalls: Vec<(usize, usize)>,
}

/// One connected worker endpoint and its liveness bookkeeping.
struct WorkerSlot {
    transport: Box<dyn Transport>,
    /// Cleared on transport error or eviction; a dead slot's id may be
    /// re-claimed by a reconnecting worker.
    alive: bool,
    /// Last instant any frame arrived on this endpoint.
    last_seen: Instant,
    /// Round this worker last acked (heartbeat after `RoundStart`):
    /// acked workers are presumed computing and get the long
    /// `round_timeout_ms` silence allowance instead of `heartbeat_ms`.
    acked_round: Option<usize>,
    /// When the slot died — silence-eviction of a dead slot waits out
    /// `protocol.rejoin_grace_ms` from here (the rejoin window).
    dead_since: Option<Instant>,
    /// Consecutive receive errors; reset on any good frame, fatal at
    /// [`RECV_ERROR_TOLERANCE`].
    recv_errors: u32,
}

impl WorkerSlot {
    /// A freshly admitted live slot.
    fn live(transport: Box<dyn Transport>, acked_round: Option<usize>) -> WorkerSlot {
        WorkerSlot {
            transport,
            alive: true,
            last_seen: Instant::now(),
            acked_round,
            dead_since: None,
            recv_errors: 0,
        }
    }
}

/// A connection that has not sent its `Hello` yet.
struct PendingConn {
    transport: Box<dyn Transport>,
    since: Instant,
}

/// What one drive of a round produced: a committed [`RoundOutcome`], or
/// a below-quorum stall that sends the machine back to STANDBY.
enum RoundAttempt {
    /// The round completed and was folded into the global model.
    Committed(RoundOutcome),
    /// Fewer than `protocol.quorum` updates survived; nothing was
    /// aggregated and the round will be retried.
    Stalled {
        /// How many updates did arrive before the stall was declared.
        survivors: usize,
    },
}

/// One flushed operator log line (piped stdout is block-buffered, and
/// the process-level chaos harness tails these lines live).
fn log_line(msg: &str) {
    use std::io::Write as _;
    println!("[fedae serve] {msg}");
    let _ = std::io::stdout().flush();
}

/// The message-driven coordinator: [`CoordinatorState`] machine,
/// rendezvous, per-round start/admit/close transitions, heartbeat
/// eviction, and the server half of the simulator's round math
/// (selection, metering, decode, aggregation, evaluation).
pub struct ProtocolServer<'rt> {
    cfg: ExperimentConfig,
    pipeline: Option<&'rt AePipeline<'rt>>,
    /// Registered population size (`fl.collaborators`).
    n_clients: usize,
    /// Model parameter count (non-AE decoder construction).
    model_n_params: usize,
    /// The AE tag every `DecoderShipment` must carry (`None` off-AE).
    ae_tag: Option<String>,
    /// Seeded selection policy — identical construction to the
    /// simulator's, so both draw the same participant sets.
    selector: Box<dyn ClientSelector>,
    /// Server aggregator (plain batch path; bitwise-equal to the
    /// simulator's streaming path).
    aggregator: Box<dyn Aggregator>,
    eval: EvalStep<'rt>,
    /// The shared test batch, gathered once (deterministic values).
    test_x: Vec<f32>,
    test_y: Vec<f32>,
    global: Vec<f32>,
    /// Simulated-cost ledger: the same `send` calls the simulator makes,
    /// driven by real frames.
    network: SimulatedNetwork,
    /// Server-side metered decoders, keyed by collaborator id.
    decoders: BTreeMap<usize, MeteredDecoder<'rt>>,
    /// Collaborators whose decoder shipment was metered (once each).
    shipped: BTreeSet<usize>,
    workers: BTreeMap<usize, WorkerSlot>,
    pending: Vec<PendingConn>,
    state: CoordinatorState,
    round: usize,
    outcomes: Vec<RoundOutcome>,
    evictions: Vec<(usize, usize)>,
    dedup_hits: u64,
    rejected_frames: u64,
    control_frames: u64,
    /// Active participants of the in-flight round (mirrors the round's
    /// `active` list for rejoin/catch-up decisions).
    cur_active: BTreeSet<usize>,
    /// Whether the in-flight round's `GlobalModel` broadcast went out —
    /// the gate for shipping params in a [`Message::CatchUp`].
    broadcast_done: bool,
    /// Participants whose update for the in-flight round was accepted
    /// (a rejoiner with an accepted update must not be re-triggered).
    uploaded: BTreeSet<usize>,
    rejoins: u64,
    conn_drops: u64,
    quorum_stalls: Vec<(usize, usize)>,
    /// Emit one flushed log line per committed round / stall (the
    /// `fedae serve` operator view).
    log_rounds: bool,
}

impl<'rt> ProtocolServer<'rt> {
    /// Validate the config and wire the server half of the experiment:
    /// selector, aggregator, eval, test batch, initial global model,
    /// simulated-cost ledger. Protocol mode is sync-barrier only and
    /// does not support checkpointing; both are rejected here.
    pub fn new(
        rt: &'rt Runtime,
        cfg: ExperimentConfig,
        pipeline: Option<&'rt AePipeline<'rt>>,
    ) -> Result<ProtocolServer<'rt>> {
        cfg.validate(rt.manifest())?;
        if cfg.engine.mode != EngineMode::Sync {
            return Err(FedAeError::Config(
                "the protocol coordinator supports engine.mode = \"sync\" only".into(),
            ));
        }
        if cfg.checkpoint.enabled() {
            return Err(FedAeError::Config(
                "checkpointing is not supported in protocol mode; use the in-process simulator"
                    .into(),
            ));
        }
        let model = rt.manifest().model(&cfg.model)?.clone();
        let kind = match cfg.model.as_str() {
            "mnist" => SynthKind::Mnist,
            "cifar" => SynthKind::Cifar,
            other => {
                return Err(FedAeError::Config(format!(
                    "no synthetic data family for model `{other}`"
                )))
            }
        };
        if cfg.data.sharding == Sharding::ColorImbalance && kind != SynthKind::Cifar {
            return Err(FedAeError::Config(
                "color_imbalance sharding requires the cifar model".into(),
            ));
        }
        let factory = ShardFactory::new(
            kind,
            cfg.data.sharding,
            cfg.data.alpha,
            cfg.data.per_collab,
            cfg.seed,
        );
        let test = factory.test_set(cfg.data.test_size)?;
        let eval = EvalStep::new(rt, &cfg.model)?;
        let test_idx: Vec<usize> = (0..test.len()).collect();
        let (test_x, test_y) = test.gather_batch(&test_idx, eval.batch);
        let global = rt.load_init(&format!("{}_params", cfg.model))?;
        let network = SimulatedNetwork::from_config(&cfg.network);
        let aggregator = crate::aggregation::from_config(&cfg.aggregation)?;
        let ae_tag = match &cfg.compression {
            CompressionConfig::Ae { ae } => {
                let pipeline = pipeline.ok_or_else(|| {
                    FedAeError::Config("AE compression requires an AePipeline".into())
                })?;
                if &pipeline.tag != ae {
                    return Err(FedAeError::Config(format!(
                        "pipeline is `{}`, config wants `{ae}`",
                        pipeline.tag
                    )));
                }
                Some(ae.clone())
            }
            _ => None,
        };
        let n_clients = cfg.fl.collaborators;
        let sel_seed = cfg.seed ^ SELECTION_SEED_TAG;
        let selector: Box<dyn ClientSelector> = match cfg.selection.policy {
            SelectionPolicy::Uniform => Box::new(UniformSelector::new(sel_seed)),
            SelectionPolicy::Weighted => Box::new(WeightedSelector::new(
                sel_seed,
                vec![cfg.data.per_collab as f64; n_clients],
            )),
            SelectionPolicy::Stratified => {
                Box::new(StratifiedSelector::new(sel_seed, cfg.selection.strata))
            }
        };
        Ok(ProtocolServer {
            n_clients,
            model_n_params: model.n_params,
            ae_tag,
            selector,
            aggregator,
            eval,
            test_x,
            test_y,
            global,
            network,
            cfg,
            pipeline,
            decoders: BTreeMap::new(),
            shipped: BTreeSet::new(),
            workers: BTreeMap::new(),
            pending: Vec::new(),
            state: CoordinatorState::Standby,
            round: 0,
            outcomes: Vec::new(),
            evictions: Vec::new(),
            dedup_hits: 0,
            rejected_frames: 0,
            control_frames: 0,
            cur_active: BTreeSet::new(),
            broadcast_done: false,
            uploaded: BTreeSet::new(),
            rejoins: 0,
            conn_drops: 0,
            quorum_stalls: Vec::new(),
            log_rounds: false,
        })
    }

    /// Emit one flushed log line per committed round and per quorum
    /// stall (off by default; `fedae serve` turns it on).
    pub fn set_round_logging(&mut self, on: bool) {
        self.log_rounds = on;
    }

    /// The machine's current protocol state.
    pub fn state(&self) -> CoordinatorState {
        self.state
    }

    /// The current global model parameters.
    pub fn global_params(&self) -> &[f32] {
        &self.global
    }

    /// The byte-exact simulated-cost ledger.
    pub fn network(&self) -> &SimulatedNetwork {
        &self.network
    }

    /// Drive the whole federation: rendezvous until
    /// `protocol.min_participants` workers joined, run every configured
    /// round (retrying below-quorum rounds from STANDBY, bounded by
    /// [`MAX_ROUND_STALLS`]), then send `Shutdown` to all live workers
    /// and report.
    pub fn run(&mut self, source: &mut dyn EndpointSource) -> Result<ProtocolReport> {
        self.rendezvous(source)?;
        let mut consecutive_stalls = 0usize;
        while self.outcomes.len() < self.cfg.fl.rounds {
            let faults_before = self.fault_counters();
            match self.run_protocol_round(source)? {
                RoundAttempt::Committed(outcome) => {
                    consecutive_stalls = 0;
                    if self.log_rounds {
                        self.log_committed(&outcome, faults_before);
                    }
                    self.outcomes.push(outcome);
                }
                RoundAttempt::Stalled { survivors } => {
                    consecutive_stalls += 1;
                    self.quorum_stalls.push((self.round, survivors));
                    if self.log_rounds {
                        log_line(&format!(
                            "round {:>3} stalled: {survivors} update(s) below quorum {}; \
                             standby rendezvous (stall {consecutive_stalls}/{MAX_ROUND_STALLS})",
                            self.round, self.cfg.protocol.quorum
                        ));
                    }
                    if consecutive_stalls >= MAX_ROUND_STALLS {
                        return Err(FedAeError::Coordination(format!(
                            "round {} stalled below quorum {} {consecutive_stalls} times in a \
                             row; giving up",
                            self.round, self.cfg.protocol.quorum
                        )));
                    }
                    self.state = CoordinatorState::Standby;
                    self.rendezvous(source)?;
                }
            }
        }
        self.state = CoordinatorState::Finished;
        let ids: Vec<usize> = self.workers.keys().copied().collect();
        for wid in ids {
            self.send_to(wid, &Message::Shutdown);
        }
        Ok(self.report())
    }

    /// Snapshot of the cumulative fault counters, for per-round deltas
    /// in the operator log.
    fn fault_counters(&self) -> [u64; 5] {
        [
            self.evictions.len() as u64,
            self.rejoins,
            self.conn_drops,
            self.dedup_hits,
            self.rejected_frames,
        ]
    }

    /// One flushed per-round operator log line with fault-counter deltas.
    fn log_committed(&self, outcome: &RoundOutcome, before: [u64; 5]) {
        let [ev, rj, cd, dd, rf] = before;
        let now = self.fault_counters();
        log_line(&format!(
            "round {:>3}/{}: eval_loss={:.4} eval_acc={:.4} up={}B down={}B admitted={} \
             evicted={} rejoined={} conn_drops={} dedup={} rejected={}",
            outcome.round,
            self.cfg.fl.rounds,
            outcome.eval_loss,
            outcome.eval_acc,
            outcome.bytes_up,
            outcome.bytes_down,
            outcome.stragglers.admitted,
            now[0] - ev,
            now[1] - rj,
            now[2] - cd,
            now[3] - dd,
            now[4] - rf,
        ));
    }

    /// The parity + fault report as of now (valid mid-run too).
    pub fn report(&self) -> ProtocolReport {
        ProtocolReport {
            outcomes: self.outcomes.clone(),
            final_params: self.global.clone(),
            ledger_totals: self.network.ledger().totals(),
            evictions: self.evictions.clone(),
            dedup_hits: self.dedup_hits,
            rejected_frames: self.rejected_frames,
            control_frames: self.control_frames,
            rejoins: self.rejoins,
            conn_drops: self.conn_drops,
            quorum_stalls: self.quorum_stalls.clone(),
        }
    }

    /// Live (non-evicted, non-errored) worker endpoints.
    fn alive_workers(&self) -> usize {
        self.workers.values().filter(|s| s.alive).count()
    }

    /// STANDBY: admit `Hello`s until `min_participants` workers are
    /// live, bounded by `round_timeout_ms`.
    fn rendezvous(&mut self, source: &mut dyn EndpointSource) -> Result<()> {
        let min = self.cfg.protocol.resolve_min_participants(self.n_clients);
        let deadline =
            Instant::now() + Duration::from_millis(self.cfg.protocol.round_timeout_ms);
        while self.alive_workers() < min {
            self.absorb_connections(source)?;
            self.poll_pending();
            let ids: Vec<usize> = self.workers.keys().copied().collect();
            for wid in ids {
                if let Some(msg) = self.pump_one(wid) {
                    self.note_stray(msg);
                }
            }
            if self.workers.is_empty() && self.pending.is_empty() {
                // Nothing to poll yet: pace the accept loop.
                std::thread::sleep(POLL);
            }
            if self.alive_workers() < min && Instant::now() > deadline {
                return Err(FedAeError::Coordination(format!(
                    "rendezvous timed out: {} of {min} workers joined",
                    self.alive_workers()
                )));
            }
        }
        Ok(())
    }

    /// Pull every waiting connection off the source into the pending
    /// (pre-`Hello`) pool.
    fn absorb_connections(&mut self, source: &mut dyn EndpointSource) -> Result<()> {
        while let Some(t) = source.poll()? {
            self.pending.push(PendingConn {
                transport: t,
                since: Instant::now(),
            });
        }
        Ok(())
    }

    /// Give every pending connection one bounded chance to produce its
    /// `Hello` or `Rejoin`; anything else (or an error, or an opener
    /// that does not arrive within the round timeout) drops the
    /// connection.
    fn poll_pending(&mut self) {
        let pending = std::mem::take(&mut self.pending);
        let patience = Duration::from_millis(self.cfg.protocol.round_timeout_ms);
        for mut conn in pending {
            match conn.transport.recv_timeout(POLL) {
                Ok(Some(Message::Hello { collab_id, version })) => {
                    self.admit(conn.transport, collab_id, version);
                }
                Ok(Some(Message::Rejoin { collab_id, .. })) => {
                    self.admit_rejoin(conn.transport, collab_id);
                }
                Ok(Some(_)) => {
                    self.rejected_frames += 1;
                }
                Ok(None) => {
                    if conn.since.elapsed() <= patience {
                        self.pending.push(conn);
                    }
                }
                Err(_) => {}
            }
        }
    }

    /// Validate a `Hello` and either install the worker slot or answer
    /// with a typed [`Message::Reject`] and drop the connection. A dead
    /// slot with the same id is replaced (reconnect).
    fn admit(&mut self, mut transport: Box<dyn Transport>, collab_id: u32, version: u16) {
        if version != PROTOCOL_VERSION {
            let _ = transport.send(&Message::Reject {
                reason: RejectReason::VersionMismatch {
                    got: version,
                    want: PROTOCOL_VERSION,
                },
            });
            self.rejected_frames += 1;
            return;
        }
        let id = collab_id as usize;
        if id >= self.n_clients {
            let _ = transport.send(&Message::Reject {
                reason: RejectReason::UnknownCollaborator { collab_id },
            });
            self.rejected_frames += 1;
            return;
        }
        if self.workers.get(&id).map(|s| s.alive).unwrap_or(false) {
            let _ = transport.send(&Message::Reject {
                reason: RejectReason::DuplicateCollaborator { collab_id },
            });
            self.rejected_frames += 1;
            return;
        }
        self.workers.insert(id, WorkerSlot::live(transport, None));
    }

    /// Re-admit a reconnecting worker: validate the id, answer with one
    /// unmetered [`Message::CatchUp`] (current round, whether the
    /// decoder shipment is still owed, and the global params when the
    /// worker is an active participant of an in-flight broadcast whose
    /// update has not arrived), and install the new endpoint. The new
    /// connection supersedes any previous slot for the id — the worker
    /// is the authority on its own connection having died.
    fn admit_rejoin(&mut self, mut transport: Box<dyn Transport>, collab_id: u32) {
        let id = collab_id as usize;
        if id >= self.n_clients {
            let _ = transport.send(&Message::Reject {
                reason: RejectReason::UnknownCollaborator { collab_id },
            });
            self.rejected_frames += 1;
            return;
        }
        let params = if self.broadcast_done
            && self.cur_active.contains(&id)
            && !self.uploaded.contains(&id)
        {
            self.global.clone()
        } else {
            Vec::new()
        };
        let catch_up = Message::CatchUp {
            round: self.round as u32,
            decoder_needed: self.ae_tag.is_some() && !self.shipped.contains(&id),
            params,
        };
        if transport.send(&catch_up).is_err() {
            // Dead again already; the worker's next redial retries.
            return;
        }
        // The rejoiner knows the round (it was just told), so it gets
        // the long computing allowance straight away.
        self.workers
            .insert(id, WorkerSlot::live(transport, Some(self.round)));
        self.rejoins += 1;
    }

    /// Bounded receive from one worker slot; updates liveness
    /// bookkeeping. Receive errors are tolerated up to
    /// [`RECV_ERROR_TOLERANCE`] consecutive failures (one malformed
    /// frame on a framed stream is survivable); past that the slot is
    /// marked dead.
    fn pump_one(&mut self, wid: usize) -> Option<Message> {
        let round = self.round;
        let slot = self.workers.get_mut(&wid)?;
        if !slot.alive {
            return None;
        }
        match slot.transport.recv_timeout(POLL) {
            Ok(Some(msg)) => {
                slot.last_seen = Instant::now();
                slot.recv_errors = 0;
                if matches!(msg, Message::Heartbeat { .. }) {
                    slot.acked_round = Some(round);
                }
                Some(msg)
            }
            Ok(None) => None,
            Err(_) => {
                slot.recv_errors += 1;
                if slot.recv_errors >= RECV_ERROR_TOLERANCE {
                    slot.alive = false;
                    slot.dead_since = Some(Instant::now());
                    self.conn_drops += 1;
                }
                None
            }
        }
    }

    /// Count a frame that needed no handling (heartbeats and other
    /// control traffic outside a round phase).
    fn note_stray(&mut self, msg: Message) {
        match msg {
            Message::Heartbeat { .. } | Message::EvalReport { .. } => self.control_frames += 1,
            _ => self.rejected_frames += 1,
        }
    }

    /// Best-effort send to a worker; transport errors kill the slot
    /// (a broken pipe on send is unambiguous, unlike a recv hiccup).
    fn send_to(&mut self, wid: usize, msg: &Message) {
        if let Some(slot) = self.workers.get_mut(&wid) {
            if slot.alive && slot.transport.send(msg).is_err() {
                slot.alive = false;
                slot.dead_since = Some(Instant::now());
                self.conn_drops += 1;
            }
        }
    }

    /// Whether `cid`'s slot is currently live.
    fn is_live(&self, cid: usize) -> bool {
        self.workers.get(&cid).map(|s| s.alive).unwrap_or(false)
    }

    /// The ids among `waiting_on` whose workers are dead past the
    /// rejoin grace, or have been silent past their allowance
    /// (`heartbeat_ms` before the round ack, `round_timeout_ms` after —
    /// an acked worker is computing).
    fn silent_among(&self, round: usize, waiting_on: &[usize], deadline: Instant) -> Vec<usize> {
        let heartbeat = Duration::from_millis(self.cfg.protocol.heartbeat_ms);
        let computing = Duration::from_millis(self.cfg.protocol.round_timeout_ms);
        let grace = Duration::from_millis(self.cfg.protocol.rejoin_grace_ms);
        let overdue = Instant::now() > deadline;
        waiting_on
            .iter()
            .copied()
            .filter(|cid| match self.workers.get(cid) {
                None => true,
                Some(s) if !s.alive => {
                    // A dropped connection gets `rejoin_grace_ms` to
                    // redial before it costs the worker its round.
                    overdue
                        || s.dead_since
                            .map(|t| t.elapsed() > grace)
                            .unwrap_or(true)
                }
                Some(s) => {
                    let allowance = if s.acked_round == Some(round) {
                        computing
                    } else {
                        heartbeat
                    };
                    overdue || s.last_seen.elapsed() > allowance
                }
            })
            .collect()
    }

    /// Register one verified decoder shipment: build the metered
    /// AE decoder, meter the upload exactly once per collaborator, and
    /// dedup byte-identical replays.
    fn handle_shipment(
        &mut self,
        round: usize,
        wid: usize,
        msg: Message,
        waiting: &mut BTreeSet<usize>,
        sel_stats: &mut SelectionStats,
    ) -> Result<()> {
        let wire = msg.wire_bytes();
        let verified = msg.verify_hash();
        let Message::DecoderShipment {
            collab_id,
            ae_tag,
            hash: _,
            dec_params,
        } = msg
        else {
            unreachable!("caller matched DecoderShipment");
        };
        let cid = collab_id as usize;
        if verified.is_err() {
            self.send_to(wid, &Message::Reject {
                reason: RejectReason::HashMismatch { collab_id },
            });
            self.rejected_frames += 1;
            return Ok(());
        }
        if cid != wid || Some(&ae_tag) != self.ae_tag.as_ref() {
            // Shipment for someone else's id, or for a different AE
            // config: a misconfigured worker that can never participate.
            self.rejected_frames += 1;
            self.kill(wid);
            return Ok(());
        }
        if self.shipped.contains(&cid) {
            // Byte-identical replay (the decoder is a pure function of
            // the shipped params): dedup, never re-meter.
            self.dedup_hits += 1;
        } else {
            let pipeline = self.pipeline.expect("AE pipeline checked at build");
            let decoder =
                MeteredDecoder::new(Box::new(AeCompressor::server(pipeline, dec_params)?));
            self.decoders.insert(cid, decoder);
            self.shipped.insert(cid);
            self.network.send(
                round,
                cid,
                Direction::Up,
                TrafficKind::DecoderShipment,
                wire,
            );
            sel_stats.newly_activated += 1;
        }
        waiting.remove(&cid);
        Ok(())
    }

    /// Mark a worker slot dead (its transport is abandoned; the id can
    /// be re-claimed by a reconnect or rejoin).
    fn kill(&mut self, cid: usize) {
        if let Some(slot) = self.workers.get_mut(&cid) {
            if slot.alive {
                slot.alive = false;
                slot.dead_since = Some(Instant::now());
            }
        }
    }

    /// Evict `cid` from the in-flight round: dead slot, removed from
    /// the barrier, recorded in the fault report. A quorum retry can
    /// re-select an already-evicted id; the `(round, cid)` pair is
    /// recorded once.
    fn evict_now(
        &mut self,
        round: usize,
        cid: usize,
        active: &mut Vec<usize>,
        state: Option<&mut RoundState>,
    ) {
        self.kill(cid);
        active.retain(|&c| c != cid);
        self.cur_active.remove(&cid);
        if let Some(state) = state {
            state.evict(cid);
        }
        if !self.evictions.contains(&(round, cid)) {
            self.evictions.push((round, cid));
        }
    }

    /// Drive one attempt at the current round: select → `RoundStart` →
    /// decoder shipments (fresh AE workers) → `GlobalModel` broadcast →
    /// collect updates + eval reports (evicting silent workers,
    /// re-admitting rejoiners) → quorum gate → decode/aggregate/eval →
    /// `RoundEnd`. The math mirrors [`super::FlDriver::run_round`]
    /// operation-for-operation — see the module docs for the parity
    /// argument. Selection is a stateless function of the round index,
    /// so a stalled attempt retries with the identical participant set.
    fn run_protocol_round(&mut self, source: &mut dyn EndpointSource) -> Result<RoundAttempt> {
        let round = self.round;
        self.state = CoordinatorState::Round(round);
        self.cur_active.clear();
        self.uploaded.clear();
        self.broadcast_done = false;
        let n = self.n_clients;
        let sample = self.cfg.selection.sample_size(n, self.cfg.fl.participation);
        let participants = self.selector.select(round, n, sample);
        let mut sel_stats = SelectionStats {
            sampled: participants.len(),
            ..SelectionStats::default()
        };

        // Round start: reset acks, notify every selected live worker;
        // selected ids with no live endpoint are evicted immediately
        // (recorded once even across quorum retries of this round).
        let mut active: Vec<usize> = Vec::with_capacity(participants.len());
        for &cid in &participants {
            if self.is_live(cid) {
                if let Some(slot) = self.workers.get_mut(&cid) {
                    slot.acked_round = None;
                }
                self.send_to(cid, &Message::RoundStart { round: round as u32 });
            }
            if self.is_live(cid) {
                active.push(cid);
            } else if !self.evictions.contains(&(round, cid)) {
                self.evictions.push((round, cid));
            }
        }
        self.cur_active = active.iter().copied().collect();

        let phase_deadline =
            Instant::now() + Duration::from_millis(self.cfg.protocol.round_timeout_ms);

        // Phase A: fresh AE participants run the pre-pass and ship
        // their decoders; non-AE decoders are pure functions of
        // (seed, id) and are built right here.
        let mut waiting: BTreeSet<usize> = BTreeSet::new();
        if self.ae_tag.is_some() {
            waiting = active
                .iter()
                .copied()
                .filter(|cid| !self.decoders.contains_key(cid))
                .collect();
        } else {
            for &cid in &active {
                if !self.decoders.contains_key(&cid) {
                    let seed = self.cfg.seed.wrapping_mul(31).wrapping_add(cid as u64);
                    let decoder = crate::compression::from_config(
                        &self.cfg.compression,
                        self.model_n_params,
                        seed,
                    )?;
                    self.decoders.insert(cid, MeteredDecoder::new(decoder));
                    sel_stats.newly_activated += 1;
                }
            }
        }
        while !waiting.is_empty() {
            self.absorb_connections(source)?;
            self.poll_pending();
            let ids: Vec<usize> = self.workers.keys().copied().collect();
            for wid in ids {
                let Some(msg) = self.pump_one(wid) else { continue };
                match msg {
                    Message::DecoderShipment { .. } => {
                        self.handle_shipment(round, wid, msg, &mut waiting, &mut sel_stats)?;
                    }
                    other => self.note_stray(other),
                }
            }
            let stalled: Vec<usize> = waiting.iter().copied().collect();
            for cid in self.silent_among(round, &stalled, phase_deadline) {
                self.evict_now(round, cid, &mut active, None);
                waiting.remove(&cid);
            }
        }

        // Broadcast the global model (metered per participant, exactly
        // like the simulator's step 1).
        let broadcast = Message::GlobalModel {
            round: round as u32,
            params: self.global.clone(),
        };
        let mut bytes_down = 0u64;
        let snapshot = active.clone();
        for &cid in &snapshot {
            self.network.send(
                round,
                cid,
                Direction::Down,
                TrafficKind::GlobalModel,
                broadcast.wire_bytes(),
            );
            bytes_down += broadcast.wire_bytes();
            self.send_to(cid, &broadcast);
            if !self.is_live(cid) {
                self.evict_now(round, cid, &mut active, None);
            }
        }
        // From here a rejoining active participant is owed the params
        // it may have missed (delivered via CatchUp, never re-metered:
        // the broadcast above was already costed).
        self.broadcast_done = true;

        // Phase B: collect one verified `EncodedUpdate` + one
        // `EvalReport` per active participant, evicting the silent.
        let mut state = RoundState::new(round, active.iter().copied());
        let mut reports: BTreeMap<usize, (f32, f32, f32, f32)> = BTreeMap::new();
        let mut arrivals: BTreeMap<usize, f64> = BTreeMap::new();
        let mut received_hash: BTreeMap<usize, u64> = BTreeMap::new();
        let mut bytes_up = 0u64;
        loop {
            let mut need: Vec<usize> = state.missing();
            for &cid in &active {
                if !reports.contains_key(&cid) && !need.contains(&cid) {
                    need.push(cid);
                }
            }
            if need.is_empty() {
                break;
            }
            self.absorb_connections(source)?;
            self.poll_pending();
            let ids: Vec<usize> = self.workers.keys().copied().collect();
            for wid in ids {
                let Some(msg) = self.pump_one(wid) else { continue };
                match msg {
                    Message::EncodedUpdate { .. } => {
                        let wire = msg.wire_bytes();
                        let verified = msg.verify_hash();
                        let Message::EncodedUpdate {
                            round: r,
                            collab_id,
                            n_samples,
                            scheme: _,
                            hash,
                            payload,
                        } = msg
                        else {
                            unreachable!("matched EncodedUpdate");
                        };
                        let cid = collab_id as usize;
                        if verified.is_err() {
                            self.send_to(wid, &Message::Reject {
                                reason: RejectReason::HashMismatch { collab_id },
                            });
                            self.rejected_frames += 1;
                            continue;
                        }
                        if r as usize != round || cid != wid {
                            self.rejected_frames += 1;
                            continue;
                        }
                        if !active.contains(&cid) {
                            self.send_to(wid, &Message::Reject {
                                reason: RejectReason::UnknownCollaborator { collab_id },
                            });
                            self.rejected_frames += 1;
                            continue;
                        }
                        if let Some(&prev) = received_hash.get(&cid) {
                            if prev == hash {
                                // Byte-identical replay: dedup, never
                                // re-meter or re-aggregate.
                                self.dedup_hits += 1;
                            } else {
                                // Two different uploads for one round:
                                // protocol violation, evict.
                                self.rejected_frames += 1;
                                self.evict_now(round, cid, &mut active, Some(&mut state));
                            }
                            continue;
                        }
                        let update = match CompressedUpdate::from_bytes(&payload) {
                            Ok(update) => update,
                            Err(_) => {
                                self.rejected_frames += 1;
                                self.evict_now(round, cid, &mut active, Some(&mut state));
                                continue;
                            }
                        };
                        let arrival_s = self.network.send(
                            round,
                            cid,
                            Direction::Up,
                            TrafficKind::Update,
                            wire,
                        );
                        bytes_up += wire;
                        received_hash.insert(cid, hash);
                        arrivals.insert(cid, arrival_s);
                        state.accept(round, cid, n_samples, update)?;
                        self.uploaded.insert(cid);
                    }
                    Message::EvalReport {
                        round: r,
                        collab_id,
                        train_loss,
                        loss,
                        acc,
                        recon_mse,
                    } => {
                        let cid = collab_id as usize;
                        self.control_frames += 1;
                        if r as usize == round && cid == wid && active.contains(&cid) {
                            reports.insert(cid, (train_loss, loss, acc, recon_mse));
                        }
                    }
                    Message::DecoderShipment { .. } => {
                        let mut ignore = BTreeSet::new();
                        self.handle_shipment(round, wid, msg, &mut ignore, &mut sel_stats)?;
                    }
                    other => self.note_stray(other),
                }
            }
            let mut need: Vec<usize> = state.missing();
            for &cid in &active {
                if !reports.contains_key(&cid) && !need.contains(&cid) {
                    need.push(cid);
                }
            }
            for cid in self.silent_among(round, &need, phase_deadline) {
                self.evict_now(round, cid, &mut active, Some(&mut state));
                reports.remove(&cid);
            }
        }

        // Fold in collaborator-id order (RoundState yields updates
        // sorted by id), mirroring the simulator's admission fold.
        let updates = state.take_updates();

        // Quorum gate: too few survivors means the attempt is not
        // committed — no aggregation, no round advance, no RoundEnd.
        // The caller returns to STANDBY and retries this round.
        let quorum = self.cfg.protocol.quorum;
        if quorum > 0 && updates.len() < quorum {
            let survivors = updates.len();
            self.cur_active.clear();
            self.uploaded.clear();
            self.broadcast_done = false;
            return Ok(RoundAttempt::Stalled { survivors });
        }

        let mut stats = StragglerStats::default();
        let mut train_losses: Vec<(usize, f32)> = Vec::with_capacity(updates.len());
        for (cid, _, _) in &updates {
            stats.admitted += 1;
            let arrival_s = *arrivals.get(cid).unwrap_or(&0.0);
            stats.sim_round_seconds = stats.sim_round_seconds.max(arrival_s);
            let report = reports.get(cid).ok_or_else(|| {
                FedAeError::Coordination(format!("missing eval report from collaborator {cid}"))
            })?;
            train_losses.push((*cid, report.0));
        }

        // Decode + aggregate, batch-materialized in id order — the
        // simulator's `agg_path = "batch"` math, bitwise-equal to its
        // streaming default. Reconstruction MSEs come from the workers'
        // eval reports (stateless decoders make them bit-identical to
        // server-side recomputation against local params).
        let mut agg_stats = AggRoundStats::default();
        let recon_mses: Vec<f32> = if updates.is_empty() {
            Vec::new()
        } else {
            agg_stats.peak_floats = (updates.len() * self.global.len()) as u64;
            let mut weighted = Vec::with_capacity(updates.len());
            let mut mses = Vec::with_capacity(updates.len());
            let staleness = vec![0usize; updates.len()];
            for (cid, n_samples, update) in updates {
                let decoder = self.decoders.get_mut(&cid).ok_or_else(|| {
                    FedAeError::Coordination(format!(
                        "no registered decoder for collaborator {cid}"
                    ))
                })?;
                let recon = decoder.decompress(&update)?;
                if let Err(i) = tensor::check_finite(&recon) {
                    return Err(FedAeError::Coordination(format!(
                        "non-finite reconstruction from collaborator {cid} at index {i}"
                    )));
                }
                mses.push(reports[&cid].3);
                weighted.push(WeightedUpdate {
                    weight: n_samples as f64,
                    values: recon,
                });
            }
            self.global = self.aggregator.aggregate_stale(weighted, &staleness, 1.0)?;
            mses
        };
        for decoder in self.decoders.values_mut() {
            let s = decoder.take_stats();
            agg_stats.full_decodes += s.full_decodes;
            agg_stats.range_decodes += s.range_decodes;
            agg_stats.decoded_floats += s.decoded_floats;
        }

        let (eval_loss, eval_acc) = self.eval.eval(&self.global, &self.test_x, &self.test_y)?;
        let mean_recon_mse = if recon_mses.is_empty() {
            f32::NAN
        } else {
            recon_mses.iter().sum::<f32>() / recon_mses.len() as f32
        };
        sel_stats.resident = self.decoders.len();

        for &cid in &active {
            self.send_to(cid, &Message::RoundEnd { round: round as u32 });
        }
        self.round += 1;
        self.cur_active.clear();
        self.uploaded.clear();
        self.broadcast_done = false;
        Ok(RoundAttempt::Committed(RoundOutcome {
            round,
            train_losses,
            eval_loss,
            eval_acc,
            mean_recon_mse,
            bytes_up,
            bytes_down,
            stragglers: stats,
            agg: agg_stats,
            selection: sel_stats,
        }))
    }
}

/// One activated worker: the training collaborator plus a private copy
/// of the server-side decoder used to report reconstruction MSE
/// (decompression is stateless, so both copies decode identically).
struct ActiveWorker<'rt> {
    collaborator: Collaborator<'rt>,
    decoder: Box<dyn UpdateCompressor + 'rt>,
    /// The decoder-shipment frame as sent (AE only) — kept for
    /// byte-identical resends after a corrupted delivery or a catch-up
    /// that reports the shipment was never received.
    shipment: Option<Message>,
}

/// Build a worker's training state as the same pure function of
/// `(seed, id)` the simulator's lazy activation uses; for the AE scheme
/// this runs the pre-pass and ships the decoder (adding the frame bytes
/// to `bytes_up`).
#[allow(clippy::too_many_arguments)]
fn activate_worker<'rt>(
    rt: &'rt Runtime,
    cfg: &ExperimentConfig,
    pipeline: Option<&'rt AePipeline<'rt>>,
    ae_init: Option<&Vec<f32>>,
    init_params: &[f32],
    model_n_params: usize,
    factory: &ShardFactory,
    id: usize,
    transport: &mut dyn Transport,
    bytes_up: &mut u64,
) -> Result<ActiveWorker<'rt>> {
    let shard: Dataset = factory.shard(id)?;
    let mut shipment = None;
    let (compressor, decoder): (Box<dyn UpdateCompressor + 'rt>, Box<dyn UpdateCompressor + 'rt>) =
        match &cfg.compression {
            CompressionConfig::Ae { ae } => {
                let pipeline = pipeline.ok_or_else(|| {
                    FedAeError::Config("AE compression requires an AePipeline".into())
                })?;
                let ae_init = ae_init.ok_or_else(|| {
                    FedAeError::Config("AE compression requires the ae init".into())
                })?;
                let pp = run_prepass(
                    rt,
                    &cfg.model,
                    pipeline,
                    &shard,
                    &cfg.prepass,
                    &cfg.train,
                    init_params,
                    ae_init,
                    cfg.seed.wrapping_add(id as u64),
                )?;
                let ship =
                    Message::decoder_shipment(id as u32, ae.clone(), pp.dec_params.clone());
                *bytes_up += transport.send(&ship)?;
                shipment = Some(ship);
                (
                    Box::new(AeCompressor::collaborator(pipeline, pp.enc_params)?)
                        as Box<dyn UpdateCompressor + 'rt>,
                    Box::new(AeCompressor::server(pipeline, pp.dec_params)?)
                        as Box<dyn UpdateCompressor + 'rt>,
                )
            }
            other => {
                let seed = cfg.seed.wrapping_mul(31).wrapping_add(id as u64);
                (
                    crate::compression::from_config(other, model_n_params, seed)?,
                    crate::compression::from_config(other, model_n_params, seed)?,
                )
            }
        };
    let collaborator = Collaborator::new(
        rt,
        &cfg.model,
        id,
        shard,
        init_params.to_vec(),
        compressor,
        cfg.seed.wrapping_add(1000 + id as u64),
    )?;
    Ok(ActiveWorker {
        collaborator,
        decoder,
        shipment,
    })
}

/// Deliver the global params for `round` on the worker side: train and
/// upload (update + eval report) the first time, resend the cached
/// byte-identical frames on any repeat delivery (quorum re-broadcast,
/// duplicated frame, catch-up after a reconnect). The training stream
/// advances exactly once per round no matter how often the round's
/// params arrive — that is what keeps faulted runs bitwise-identical.
#[allow(clippy::too_many_arguments)]
fn deliver_round<'rt>(
    worker: &mut ActiveWorker<'rt>,
    trained: &mut Option<(u32, Message, Message)>,
    eval: &EvalStep<'rt>,
    test_x: &[f32],
    test_y: &[f32],
    cfg: &ExperimentConfig,
    id: usize,
    round: u32,
    params: &[f32],
    transport: &mut dyn Transport,
    report: &mut WorkerReport,
) -> Result<()> {
    if trained.as_ref().map(|(r, _, _)| *r) == Some(round) {
        let (_, upd, rep) = trained.as_ref().expect("round checked above");
        transport.send(upd)?;
        transport.send(rep)?;
        report.resends += 1;
        return Ok(());
    }
    worker.collaborator.set_global(params);
    let train_loss = worker
        .collaborator
        .local_train(cfg.fl.local_epochs, &cfg.train)?;
    let (loss, acc) = eval.eval(worker.collaborator.params(), test_x, test_y)?;
    let update = worker.collaborator.compressed_update(round as usize)?;
    let recon = worker.decoder.decompress(&update)?;
    let recon_mse = tensor::mse(&recon, worker.collaborator.params()) as f32;
    let upd_msg = Message::encoded_update(
        round,
        id as u32,
        worker.collaborator.n_samples() as u32,
        update.to_bytes(),
    );
    report.bytes_up += transport.send(&upd_msg)?;
    let rep_msg = Message::EvalReport {
        round,
        collab_id: id as u32,
        train_loss,
        loss,
        acc,
        recon_mse,
    };
    transport.send(&rep_msg)?;
    report.rounds_participated += 1;
    *trained = Some((round, upd_msg, rep_msg));
    Ok(())
}

/// Accounting a worker hands back after a clean `Shutdown`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerReport {
    /// Rounds this worker trained in and uploaded for.
    pub rounds_participated: usize,
    /// Data-plane bytes sent (updates + decoder shipment).
    pub bytes_up: u64,
    /// Idle heartbeats sent.
    pub heartbeats_sent: u64,
    /// [`Message::CatchUp`] frames received after rejoining.
    pub catch_ups: u64,
    /// Byte-identical data-plane resends (after a corrupted delivery
    /// was rejected, a duplicate round delivery, or a catch-up).
    pub resends: u64,
}

/// The worker half of the protocol: `Hello`, then react to coordinator
/// frames until `Shutdown` — ack each `RoundStart` with a heartbeat,
/// activate lazily on first selection (AE pre-pass + decoder shipment),
/// and answer each `GlobalModel` with local training, an
/// [`Message::encoded_update`] and an [`Message::EvalReport`].
/// Heartbeats are sent whenever the line goes idle for a third of
/// `protocol.heartbeat_ms`.
///
/// Fault recovery (v3): repeat deliveries of a round's params —
/// duplicated frames, quorum re-broadcasts, [`Message::CatchUp`] after
/// a reconnect — resend the cached byte-identical frames instead of
/// retraining, a [`RejectReason::HashMismatch`] triggers the same
/// resend, and only non-recoverable rejects abort the worker. Wrap the
/// transport in a [`crate::transport::retry::ReconnectingTransport`]
/// (as `fedae worker` does) to survive dropped connections: it redials
/// and opens with [`Message::Rejoin`] transparently.
///
/// Every seeded stream matches the simulator's per-client activation,
/// so a federation of these workers reproduces the in-process run
/// bitwise (see the module docs).
pub fn run_worker<'rt>(
    rt: &'rt Runtime,
    cfg: &ExperimentConfig,
    pipeline: Option<&'rt AePipeline<'rt>>,
    id: usize,
    transport: &mut dyn Transport,
) -> Result<WorkerReport> {
    cfg.validate(rt.manifest())?;
    if id >= cfg.fl.collaborators {
        return Err(FedAeError::Config(format!(
            "worker id {id} out of range for {} collaborators",
            cfg.fl.collaborators
        )));
    }
    let model = rt.manifest().model(&cfg.model)?.clone();
    let kind = match cfg.model.as_str() {
        "mnist" => SynthKind::Mnist,
        "cifar" => SynthKind::Cifar,
        other => {
            return Err(FedAeError::Config(format!(
                "no synthetic data family for model `{other}`"
            )))
        }
    };
    let factory = ShardFactory::new(
        kind,
        cfg.data.sharding,
        cfg.data.alpha,
        cfg.data.per_collab,
        cfg.seed,
    );
    let test = factory.test_set(cfg.data.test_size)?;
    let eval = EvalStep::new(rt, &cfg.model)?;
    let test_idx: Vec<usize> = (0..test.len()).collect();
    let (test_x, test_y) = test.gather_batch(&test_idx, eval.batch);
    let init_params = rt.load_init(&format!("{}_params", cfg.model))?;
    let ae_init = match &cfg.compression {
        CompressionConfig::Ae { ae } => {
            let pipeline = pipeline.ok_or_else(|| {
                FedAeError::Config("AE compression requires an AePipeline".into())
            })?;
            if &pipeline.tag != ae {
                return Err(FedAeError::Config(format!(
                    "pipeline is `{}`, config wants `{ae}`",
                    pipeline.tag
                )));
            }
            Some(rt.load_init(&format!("ae_{ae}_init"))?)
        }
        _ => None,
    };

    let mut report = WorkerReport::default();
    transport.send(&Message::Hello {
        collab_id: id as u32,
        version: PROTOCOL_VERSION,
    })?;
    let tick = Duration::from_millis((cfg.protocol.heartbeat_ms / 3).max(10));
    let mut state: Option<ActiveWorker<'rt>> = None;
    // The last round trained for, with the update/report frames as
    // sent — repeat deliveries resend these instead of retraining.
    let mut trained: Option<(u32, Message, Message)> = None;
    // The round the coordinator most recently told us about (gates
    // which cached frames a hash-mismatch recovery may resend).
    let mut cur_round: Option<u32> = None;
    loop {
        match transport.recv_timeout(tick)? {
            None => {
                transport.send(&Message::Heartbeat {
                    collab_id: id as u32,
                })?;
                report.heartbeats_sent += 1;
            }
            Some(Message::RoundStart { round }) => {
                cur_round = Some(round);
                // Ack first so the coordinator extends the silence
                // allowance over the (possibly long) pre-pass.
                transport.send(&Message::Heartbeat {
                    collab_id: id as u32,
                })?;
                if state.is_none() {
                    state = Some(activate_worker(
                        rt,
                        cfg,
                        pipeline,
                        ae_init.as_ref(),
                        &init_params,
                        model.n_params,
                        &factory,
                        id,
                        transport,
                        &mut report.bytes_up,
                    )?);
                }
            }
            Some(Message::GlobalModel { round, params }) => {
                cur_round = Some(round);
                if state.is_none() {
                    state = Some(activate_worker(
                        rt,
                        cfg,
                        pipeline,
                        ae_init.as_ref(),
                        &init_params,
                        model.n_params,
                        &factory,
                        id,
                        transport,
                        &mut report.bytes_up,
                    )?);
                }
                let worker = state.as_mut().expect("activated above");
                deliver_round(
                    worker,
                    &mut trained,
                    &eval,
                    &test_x,
                    &test_y,
                    cfg,
                    id,
                    round,
                    &params,
                    transport,
                    &mut report,
                )?;
            }
            Some(Message::CatchUp {
                round,
                decoder_needed,
                params,
            }) => {
                // Reconnection state transfer: the coordinator tells us
                // the current round, whether it still needs our decoder
                // shipment, and (when we are an in-flight participant
                // whose update it lacks) the params we missed.
                cur_round = Some(round);
                report.catch_ups += 1;
                let was_active = state.is_some();
                if state.is_none() && (decoder_needed || !params.is_empty()) {
                    // Activation ships the decoder as a side effect, so
                    // a decoder owed by a fresh (restarted) worker is
                    // covered here.
                    state = Some(activate_worker(
                        rt,
                        cfg,
                        pipeline,
                        ae_init.as_ref(),
                        &init_params,
                        model.n_params,
                        &factory,
                        id,
                        transport,
                        &mut report.bytes_up,
                    )?);
                }
                if let Some(worker) = state.as_mut() {
                    if decoder_needed && was_active {
                        if let Some(ship) = &worker.shipment {
                            transport.send(ship)?;
                            report.resends += 1;
                        }
                    }
                    if !params.is_empty() {
                        deliver_round(
                            worker,
                            &mut trained,
                            &eval,
                            &test_x,
                            &test_y,
                            cfg,
                            id,
                            round,
                            &params,
                            transport,
                            &mut report,
                        )?;
                    }
                }
            }
            Some(Message::RoundEnd { .. }) => {}
            Some(Message::Reject {
                reason: RejectReason::HashMismatch { .. },
            }) => {
                // A data-plane frame arrived corrupted (lossy link):
                // resend the cached byte-identical frames — the
                // coordinator dedups whichever copies it already has by
                // content hash. Other rejects stay fatal below.
                if let Some(worker) = state.as_ref() {
                    if let Some(ship) = &worker.shipment {
                        transport.send(ship)?;
                        report.resends += 1;
                    }
                }
                if let Some((r, upd, rep)) = trained.as_ref() {
                    if cur_round == Some(*r) {
                        transport.send(upd)?;
                        transport.send(rep)?;
                        report.resends += 1;
                    }
                }
            }
            Some(Message::Reject { reason }) => {
                return Err(FedAeError::Protocol(format!(
                    "rejected by coordinator: {reason}"
                )));
            }
            Some(Message::Shutdown) => break,
            Some(_) => {}
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "mnist".into();
        cfg.fl.collaborators = 2;
        cfg.fl.rounds = 1;
        cfg.fl.local_epochs = 1;
        cfg.data.per_collab = 32;
        cfg.data.test_size = 32;
        cfg.compression = CompressionConfig::Identity;
        cfg
    }

    #[test]
    fn starts_in_standby() {
        let rt = Runtime::native().unwrap();
        let server = ProtocolServer::new(&rt, tiny_cfg(), None).unwrap();
        assert_eq!(server.state(), CoordinatorState::Standby);
        assert_eq!(format!("{}", server.state()), "STANDBY");
        assert_eq!(format!("{}", CoordinatorState::Round(3)), "ROUND(3)");
        assert_eq!(format!("{}", CoordinatorState::Finished), "FINISHED");
    }

    #[test]
    fn rejects_async_mode_and_checkpointing() {
        let rt = Runtime::native().unwrap();
        let mut cfg = tiny_cfg();
        cfg.engine.mode = EngineMode::Async;
        cfg.engine.deadline_ms = 100.0;
        let err = ProtocolServer::new(&rt, cfg, None).unwrap_err();
        assert!(err.to_string().contains("sync"), "got: {err}");

        let mut cfg = tiny_cfg();
        cfg.checkpoint.dir = "/tmp/nope".into();
        let err = ProtocolServer::new(&rt, cfg, None).unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "got: {err}");
    }

    #[test]
    fn channel_endpoints_polls_pushed_transports() {
        let (tx, mut src) = ChannelEndpoints::new();
        assert!(src.poll().unwrap().is_none());
        let (server_end, _worker_end) = crate::transport::InProcChannel::pair();
        tx.send(Box::new(server_end)).unwrap();
        assert!(src.poll().unwrap().is_some());
        // A dropped dial sender is not an error: the coordinator keeps
        // serving whatever endpoints it already has.
        drop(tx);
        assert!(src.poll().unwrap().is_none());
    }

    #[test]
    fn rendezvous_times_out_without_workers() {
        let rt = Runtime::native().unwrap();
        let mut cfg = tiny_cfg();
        cfg.protocol.round_timeout_ms = 50;
        let mut server = ProtocolServer::new(&rt, cfg, None).unwrap();
        let mut source = StaticEndpoints::new(Vec::new());
        let err = server.run(&mut source).unwrap_err();
        assert!(
            err.to_string().contains("rendezvous timed out"),
            "got: {err}"
        );
    }
}
