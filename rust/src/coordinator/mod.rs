//! Aggregator/coordinator: the server side of the federation.
//!
//! * [`RoundState`] — per-round state machine accepting updates with
//!   duplicate / stale / unknown-collaborator protection.
//! * [`DecoderRegistry`] — decoders shipped at the end of the pre-pass
//!   round, keyed by collaborator (paper §5.3 case (b)) or shared
//!   (case (a)); thread-safe so parallel pre-pass workers can register
//!   directly.
//! * [`ParallelRoundEngine`] (in [`engine`]) — the scoped-thread fan-out
//!   that runs per-collaborator round work (local train → AE encode →
//!   simulated send) concurrently, deterministically.
//! * [`AsyncRoundEngine`] (in [`async_engine`]) — the deadline-driven
//!   round discipline: seeded straggler/dropout modelling, deadline
//!   admission, late-update buffering and staleness accounting.
//! * [`FlDriver`] — the in-process experiment driver: wires collaborators,
//!   compressors, aggregation, the simulated network and metrics into the
//!   paper's federated loop (Fig 3), including the pre-pass round (Fig 2).
//!   Three execution knobs ([`crate::config::EngineConfig`]) scale it to
//!   large federations: `parallelism` fans collaborator work (and, on the
//!   streaming server path, independent aggregation shards) across
//!   workers; `shard_size` partitions server aggregation into coordinate
//!   shards; and `agg_path` selects between the batch server path and
//!   the streaming accumulator path (one full decode per update, O(n)
//!   server memory for the linear aggregators — see
//!   [`FlDriver::run_round`] step 5 and ARCHITECTURE.md §Server cost
//!   model). None of the three changes results: see
//!   `rust/tests/parallel_round.rs` and `rust/tests/streaming_agg.rs`.
//!   A fourth knob family (`engine.mode = "async"` + deadline/straggler
//!   knobs) swaps the round barrier for the deadline discipline — that
//!   one *does* change results, deterministically (ARCHITECTURE.md
//!   §Async rounds & staleness, `rust/tests/async_round.rs`).
//! * [`ClientSelector`] (in [`selection`]) — seeded per-round client
//!   selection (uniform / weighted / stratified K-of-N), a pure function
//!   of `(seed, round, policy)`. The driver pairs it with a lazy
//!   resident-state pool: collaborator state (shard, local model,
//!   compressor, server decoder) is built on first selection and, under
//!   `selection.max_resident`, evicted least-recently-selected — so
//!   driver memory is O(active ∪ recently-active), not O(registered),
//!   and million-client populations are simulable (ARCHITECTURE.md
//!   §Client selection & lazy state, `rust/tests/selection.rs`).
//! * [`checkpoint`] — versioned snapshots + an append-only per-round
//!   event log. A snapshot captures the complete cross-round state;
//!   [`DriverBuilder::resume_from`] rebuilds everything else as a pure
//!   function of `(config, seed)`, so a resumed run replays the
//!   remaining rounds bitwise-identically to the uninterrupted one
//!   (ARCHITECTURE.md §Checkpointing & replay,
//!   `rust/tests/checkpoint.rs`).
//! * [`protocol`] — the message-driven coordinator state machine and
//!   worker loop: STANDBY/ROUND/FINISHED transitions, rendezvous,
//!   heartbeat-deadline eviction, hash-verified frames, and bitwise
//!   parity with this in-process driver over in-proc or TCP transports
//!   (ARCHITECTURE.md §Coordinator protocol & transports,
//!   `rust/tests/protocol.rs`).

pub mod async_engine;
pub mod checkpoint;
pub mod engine;
pub mod protocol;
pub mod selection;

pub use async_engine::{AsyncRoundEngine, BufferedUpdate, StragglerStats};
pub use checkpoint::{Checkpointer, EventRecord, Snapshot};
pub use engine::ParallelRoundEngine;
pub use protocol::{
    run_worker, ChannelEndpoints, CoordinatorState, EndpointSource, ProtocolReport,
    ProtocolServer, StaticEndpoints, TcpAcceptor, WorkerReport, MAX_ROUND_STALLS,
    RECV_ERROR_TOLERANCE,
};
pub use selection::{
    ClientSelector, SelectionStats, StratifiedSelector, UniformSelector, WeightedSelector,
};

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, RwLock};

use crate::aggregation::{
    sharded::shard_ranges, Aggregator, ShardedAggregator, StreamPlan, WeightedUpdate,
};
use crate::collaborator::{run_prepass, Collaborator, PrepassResult};
use crate::compression::{ae::AeCompressor, CompressedUpdate, MeteredDecoder, UpdateCompressor};
use crate::config::{AggPath, CompressionConfig, ExperimentConfig, SelectionPolicy, Sharding};
use crate::data::{Dataset, ShardFactory, SynthKind};
use crate::error::{FedAeError, Result};
use crate::metrics::{ExperimentLog, RoundRecord};
use crate::network::{
    Direction, SimulatedNetwork, StragglerModel, TrafficKind, TrafficLedger, Transfer, UploadFate,
};
use crate::runtime::{AePipeline, EvalStep, Runtime};
use crate::tensor;
use crate::transport::Message;
use crate::util::Stopwatch;

/// Per-round server state machine.
#[derive(Debug)]
pub struct RoundState {
    /// The round this state machine accepts updates for.
    pub round: usize,
    expected: BTreeSet<usize>,
    received: BTreeMap<usize, (u32, CompressedUpdate)>,
}

impl RoundState {
    /// A fresh round expecting updates from `expected` collaborators.
    pub fn new(round: usize, expected: impl IntoIterator<Item = usize>) -> RoundState {
        RoundState {
            round,
            expected: expected.into_iter().collect(),
            received: BTreeMap::new(),
        }
    }

    /// Accept one update; enforces protocol invariants.
    pub fn accept(
        &mut self,
        round: usize,
        collab: usize,
        n_samples: u32,
        update: CompressedUpdate,
    ) -> Result<()> {
        if round != self.round {
            return Err(FedAeError::Coordination(format!(
                "stale/early update: got round {round}, current {}",
                self.round
            )));
        }
        if !self.expected.contains(&collab) {
            return Err(FedAeError::Coordination(format!(
                "unknown or unselected collaborator {collab} for round {round}"
            )));
        }
        if self.received.contains_key(&collab) {
            return Err(FedAeError::Coordination(format!(
                "duplicate update from collaborator {collab} in round {round}"
            )));
        }
        self.received.insert(collab, (n_samples, update));
        Ok(())
    }

    /// True when every expected update has arrived.
    pub fn is_complete(&self) -> bool {
        self.received.len() == self.expected.len()
    }

    /// Number of updates received so far.
    pub fn received_count(&self) -> usize {
        self.received.len()
    }

    /// Expected collaborators that have not reported yet.
    pub fn missing(&self) -> Vec<usize> {
        self.expected
            .iter()
            .filter(|c| !self.received.contains_key(c))
            .copied()
            .collect()
    }

    /// Evict a collaborator from the round: it is no longer expected
    /// (and any update it already delivered is discarded), so the round
    /// can complete without it. Returns `true` if the collaborator was
    /// part of the round. Used by the protocol coordinator's
    /// heartbeat-deadline eviction ([`protocol`]).
    pub fn evict(&mut self, collab: usize) -> bool {
        let was_expected = self.expected.remove(&collab);
        self.received.remove(&collab);
        was_expected
    }

    /// Drain the received updates (ordered by collaborator id).
    pub fn take_updates(self) -> Vec<(usize, u32, CompressedUpdate)> {
        self.received
            .into_iter()
            .map(|(c, (n, u))| (c, n, u))
            .collect()
    }
}

/// Decoders shipped to the server at the end of the pre-pass round.
///
/// Registrations arrive from the parallel pre-pass workers, so the map
/// lives behind a `RwLock` and both [`DecoderRegistry::register`] and
/// [`DecoderRegistry::get`] take `&self`; decoder parameter vectors are
/// handed out as cheap [`Arc`] clones. Registration order does not matter
/// (the map is keyed by collaborator id), which is what makes concurrent
/// pre-pass registration deterministic.
#[derive(Debug, Default)]
pub struct DecoderRegistry {
    decoders: RwLock<BTreeMap<usize, Arc<Vec<f32>>>>,
}

impl DecoderRegistry {
    /// Register one collaborator's decoder half; rejects duplicates.
    pub fn register(&self, collab: usize, dec_params: Vec<f32>) -> Result<()> {
        let mut map = self.decoders.write().expect("decoder registry poisoned");
        if map.contains_key(&collab) {
            return Err(FedAeError::Coordination(format!(
                "decoder already registered for collaborator {collab}"
            )));
        }
        map.insert(collab, Arc::new(dec_params));
        Ok(())
    }

    /// Fetch a collaborator's decoder parameters.
    pub fn get(&self, collab: usize) -> Result<Arc<Vec<f32>>> {
        self.decoders
            .read()
            .expect("decoder registry poisoned")
            .get(&collab)
            .cloned()
            .ok_or_else(|| {
                FedAeError::Coordination(format!(
                    "no decoder registered for collaborator {collab}"
                ))
            })
    }

    /// Remove a collaborator's decoder, if present. A no-op when absent.
    ///
    /// Used by the driver's resident-pool eviction: the registry models
    /// what the simulated server holds in *memory*, not the wire
    /// protocol — the decoder shipment itself is metered only once per
    /// collaborator, and re-registration after re-selection restores the
    /// bit-identical parameters (the pre-pass is a pure function of its
    /// seed).
    pub fn unregister(&self, collab: usize) {
        self.decoders
            .write()
            .expect("decoder registry poisoned")
            .remove(&collab);
    }

    /// Number of registered decoders.
    pub fn len(&self) -> usize {
        self.decoders.read().expect("decoder registry poisoned").len()
    }

    /// True when no decoder has been registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Server aggregation cost accounting for one round: the decode meter
/// readings ([`crate::compression::DecodeStats`] drained from every
/// [`MeteredDecoder`]), the aggregation path's modelled peak memory, and
/// its wall-clock time.
///
/// This is *execution* metadata, not a result: two bitwise-identical
/// runs legitimately differ here (wall time always; decode shape
/// whenever `agg_path`/`shard_size` differ), so [`RoundOutcome`]'s
/// `PartialEq` ignores it entirely. It is surfaced per round in the CLI
/// log (`agg_decodes`/`agg_peak_floats`/`agg_ms`) and summed into the
/// experiment-log summaries, sharing one source of truth with the bench
/// JSON (`rust/benches/bench_streaming_agg.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AggRoundStats {
    /// Full-vector decodes performed during aggregation. On the
    /// streaming path this is exactly one per update — asserted by
    /// `rust/tests/streaming_agg.rs`, not assumed.
    pub full_decodes: u64,
    /// Random-access range decodes performed during aggregation (the
    /// shard-major batch path over random-access schemes).
    pub range_decodes: u64,
    /// How many of the full decodes ran inside a batched
    /// `decompress_batch` of two or more same-decoder updates (each still
    /// counts one full decode; this measures the amortization).
    pub batched_decodes: u64,
    /// Total floats the decode meter saw reconstructed.
    pub decoded_floats: u64,
    /// Peak floats the aggregation path buffers at once — accumulators
    /// plus reconstruction buffers, by the deterministic cost model in
    /// ARCHITECTURE.md §Server cost model (scheme-internal transients of
    /// full-decode range calls are counted by `decoded_floats`, not
    /// here).
    pub peak_floats: u64,
    /// Wall-clock milliseconds spent reconstructing + aggregating.
    pub ms: f64,
}

impl AggRoundStats {
    /// Fold one round's accounting into a running experiment total
    /// (counts and wall time sum; `peak_floats` takes the max).
    pub fn accumulate(&mut self, round: &AggRoundStats) {
        self.full_decodes += round.full_decodes;
        self.range_decodes += round.range_decodes;
        self.batched_decodes += round.batched_decodes;
        self.decoded_floats += round.decoded_floats;
        self.peak_floats = self.peak_floats.max(round.peak_floats);
        self.ms += round.ms;
    }
}

/// Outcome of one communication round.
///
/// Compares with `==` field-by-field, except `mean_recon_mse` which is
/// compared bitwise — `NaN` there marks "no fresh updates this round"
/// (an async round where everything was late or dropped), and two
/// bit-identical runs must still compare equal — and `agg` and
/// `selection`, which are execution metadata (wall-clock, decode/memory
/// accounting, resident-pool churn) and are excluded so runs that
/// differ only in `parallelism`/`shard_size`/`agg_path`/`max_resident`
/// still compare equal. The determinism tests rely on all three.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Which round this outcome describes.
    pub round: usize,
    /// (collaborator, local train loss).
    pub train_losses: Vec<(usize, f32)>,
    /// Post-aggregation global eval.
    pub eval_loss: f32,
    /// Post-aggregation global eval accuracy.
    pub eval_acc: f32,
    /// Mean reconstruction MSE across updates (NaN for lossless).
    pub mean_recon_mse: f32,
    /// Uplink bytes this round (updates).
    pub bytes_up: u64,
    /// Downlink bytes this round (global-model broadcasts).
    pub bytes_down: u64,
    /// Deadline/straggler accounting (all-admitted in sync mode).
    pub stragglers: StragglerStats,
    /// Server aggregation cost accounting (excluded from `==`).
    pub agg: AggRoundStats,
    /// Client-selection and resident-pool accounting (excluded from
    /// `==`).
    pub selection: SelectionStats,
}

impl PartialEq for RoundOutcome {
    fn eq(&self, other: &RoundOutcome) -> bool {
        self.round == other.round
            && self.train_losses == other.train_losses
            && self.eval_loss == other.eval_loss
            && self.eval_acc == other.eval_acc
            && self.mean_recon_mse.to_bits() == other.mean_recon_mse.to_bits()
            && self.bytes_up == other.bytes_up
            && self.bytes_down == other.bytes_down
            && self.stragglers == other.stragglers
    }
}

/// Per-collaborator result of one round's fanned-out work (local train,
/// local eval, compression, metered upload) — produced on an engine
/// worker, consumed on the coordinator thread in collaborator-id order.
struct CollabRoundResult {
    cid: usize,
    n_samples: u32,
    train_loss: f32,
    local_eval_loss: f32,
    local_eval_acc: f32,
    update: CompressedUpdate,
    /// Worker-private traffic ledger, merged into the round network.
    ledger: TrafficLedger,
    /// Modelled upload fate: always on-time arrival in sync mode; the
    /// seeded [`StragglerModel`] decides in async mode.
    fate: UploadFate,
}

/// The driver's server-side aggregator: the plain configured algorithm,
/// or the [`ShardedAggregator`] adapter when `engine.shard_size > 0`.
/// Kept as an enum (not a `Box<dyn Aggregator>`) so the streaming path
/// can open the adapter's per-shard accumulator streams and fan them
/// across workers.
enum ServerAggregator {
    /// Unsharded: one whole-vector aggregator.
    Plain(Box<dyn Aggregator>),
    /// Coordinate-sharded: per-shard inner aggregator instances.
    Sharded(ShardedAggregator),
}

impl ServerAggregator {
    /// The uniform [`Aggregator`] view (batch paths).
    fn as_aggregator(&mut self) -> &mut dyn Aggregator {
        match self {
            ServerAggregator::Plain(a) => a.as_mut(),
            ServerAggregator::Sharded(s) => s,
        }
    }

    /// Whether the configured algorithm streams natively (linear
    /// aggregators fold in O(width) state).
    fn supports_streaming(&self) -> bool {
        match self {
            ServerAggregator::Plain(a) => a.supports_streaming(),
            ServerAggregator::Sharded(s) => s.supports_streaming(),
        }
    }

    /// Export the cross-round aggregator state for a snapshot.
    fn export_state(&self) -> Vec<u8> {
        match self {
            ServerAggregator::Plain(a) => a.export_state(),
            ServerAggregator::Sharded(s) => s.export_state(),
        }
    }

    /// Restore aggregator state from a snapshot blob.
    fn import_state(&mut self, bytes: &[u8]) -> Result<()> {
        match self {
            ServerAggregator::Plain(a) => a.import_state(bytes),
            ServerAggregator::Sharded(s) => s.import_state(bytes),
        }
    }
}

/// One client's resident state: the collaborator (shard, local model,
/// batch stream, compressor) plus the server-side metered decompressor
/// for its updates. Built lazily on first selection
/// ([`FlDriver`] activation) and — under `selection.max_resident` —
/// evicted least-recently-selected, to be rebuilt bit-identically on
/// re-selection.
struct ClientState<'rt> {
    collaborator: Collaborator<'rt>,
    /// Server-side decompressor wrapped in the decode meter so every
    /// reconstruction during aggregation is counted
    /// ([`crate::compression::DecodeStats`]).
    decoder: MeteredDecoder<'rt>,
    /// Round this client was last selected (the LRU eviction key).
    last_used: usize,
}

/// Tag XORed into the experiment seed to derive the client-selection
/// stream, decorrelating it from the sharding / init / training streams
/// (which derive from the raw seed).
const SELECTION_SEED_TAG: u64 = 0x5E1E_C7ED_0C1A_55E5;

/// The whole-experiment driver (single-process simulation).
///
/// Built via [`FlDriver::builder`]. Collaborator state is *not* built up
/// front: each round the [`ClientSelector`] picks K of the N registered
/// clients, and only picked clients are activated (shard synthesized,
/// pre-pass run, compressors built) — everything an unpicked client
/// would contribute is deferred, so construction and per-round cost
/// scale with K, not N.
pub struct FlDriver<'rt> {
    cfg: ExperimentConfig,
    rt: &'rt Runtime,
    /// Resident client state, keyed by client id. Holds O(active ∪
    /// recently-active) entries: clients activate on first selection and
    /// are evicted least-recently-selected when `selection.max_resident`
    /// bounds the pool.
    clients: BTreeMap<usize, ClientState<'rt>>,
    /// Registered population size (`fl.collaborators`) — the N that
    /// selection draws from; never materialized as a collection.
    n_clients: usize,
    /// Per-round seeded selection policy.
    selector: Box<dyn ClientSelector>,
    /// Lazy shard synthesis: any client's dataset on demand.
    factory: ShardFactory,
    /// AE pipeline (required when `cfg.compression` is `ae`), kept for
    /// lazy activation pre-passes.
    pipeline: Option<&'rt AePipeline<'rt>>,
    /// Model parameter count (non-AE compressor construction).
    model_n_params: usize,
    /// The frozen initial global model: activation always starts a
    /// client from this (its locals are overwritten by the broadcast
    /// anyway), so a re-activated client is bit-identical to one that
    /// was never evicted.
    init_params: Vec<f32>,
    /// The frozen AE initialization used by every activation pre-pass.
    ae_init: Option<Vec<f32>>,
    /// Decoders currently registered server-side (AE scheme; mirrors
    /// the resident pool).
    registry: DecoderRegistry,
    /// Clients whose decoder shipment was already metered: eviction
    /// models server *memory*, so a re-activation re-registers the
    /// decoder without re-paying the (identical) shipment bytes.
    shipped: BTreeSet<usize>,
    /// Batch-stream positions of evicted clients: re-activation
    /// fast-forwards the rebuilt collaborator's batch iterator so its
    /// draw sequence continues exactly where the evicted one stopped.
    suspended: BTreeMap<usize, u64>,
    /// The round aggregator. The streaming path
    /// ([`crate::config::AggPath`]) folds one reconstruction at a time
    /// into accumulator streams (per shard when sharded); the batch path
    /// drives [`Aggregator::aggregate_stale`] /
    /// [`Aggregator::aggregate_shard_stale`] exactly as before.
    server_agg: ServerAggregator,
    /// Fan-out pool for per-collaborator round work.
    engine: ParallelRoundEngine,
    /// Deadline-driven round discipline (`engine.mode = "async"` only):
    /// straggler model, deadline admission and the late-update buffer.
    async_engine: Option<AsyncRoundEngine>,
    /// Snapshot/event-log writer (`checkpoint.dir` set); `None` disables
    /// checkpointing entirely.
    checkpointer: Option<Checkpointer>,
    /// The simulated network + byte-exact traffic ledger.
    pub network: SimulatedNetwork,
    eval: EvalStep<'rt>,
    test: Dataset,
    global: Vec<f32>,
    /// Per-round records and experiment summaries.
    pub log: ExperimentLog,
    /// Pre-pass results, one per *activated* AE collaborator in first-
    /// activation order (kept for figures/validation).
    pub prepass_results: Vec<PrepassResult>,
    round: usize,
}

impl<'rt> std::fmt::Debug for FlDriver<'rt> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlDriver")
            .field("experiment", &self.cfg.name)
            .field("registered", &self.n_clients)
            .field("resident", &self.clients.len())
            .field("round", &self.round)
            .finish()
    }
}

/// Staged construction for [`FlDriver`]: the required wiring goes into
/// [`FlDriver::builder`], optional parts land as named methods instead
/// of a widening positional signature.
///
/// ```ignore
/// let mut driver = FlDriver::builder(&rt, cfg).pipeline(&pipeline).build()?;
/// ```
pub struct DriverBuilder<'rt> {
    rt: &'rt Runtime,
    cfg: ExperimentConfig,
    pipeline: Option<&'rt AePipeline<'rt>>,
    resume: Option<PathBuf>,
}

impl<'rt> DriverBuilder<'rt> {
    /// Attach the AE pipeline — required when `cfg.compression` is `ae`,
    /// rejected-at-build otherwise unused.
    pub fn pipeline(mut self, pipeline: &'rt AePipeline<'rt>) -> Self {
        self.pipeline = Some(pipeline);
        self
    }

    /// Resume from a snapshot: a `.ckpt` file, or a checkpoint directory
    /// (the newest snapshot in it is used). The snapshot's config
    /// fingerprint must match `cfg` — same seed, model, topology,
    /// compression, aggregation, engine mode and selection policy — or
    /// the build fails with a [`FedAeError::Checkpoint`] naming the
    /// mismatched field. After a successful restore, rounds
    /// `snapshot.round..fl.rounds` replay bitwise-identically to the
    /// uninterrupted run.
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(path.into());
        self
    }

    /// Validate the config and wire the experiment: shared test set,
    /// aggregator, engines, network, selection policy. Per-client state
    /// (shards, pre-passes, compressors) is created lazily when a client
    /// is first selected, so building is O(1) in the registered
    /// population. With [`DriverBuilder::resume_from`], the snapshot is
    /// then loaded, validated and restored, and the event log truncated
    /// at the resume round (repairing any crash between a round's event
    /// append and its snapshot write).
    pub fn build(self) -> Result<FlDriver<'rt>> {
        let DriverBuilder {
            rt,
            cfg,
            pipeline,
            resume,
        } = self;
        let mut driver = FlDriver::from_parts(rt, cfg, pipeline)?;
        if let Some(path) = resume {
            let file = if path.is_dir() {
                checkpoint::latest_snapshot(&path)?.ok_or_else(|| {
                    FedAeError::Checkpoint(format!(
                        "no snapshot found in `{}`",
                        path.display()
                    ))
                })?
            } else {
                path
            };
            let snap = Snapshot::read_from(&file)?;
            driver.restore_from(snap)?;
            if let Some(ck) = &driver.checkpointer {
                ck.truncate_events_from(driver.round)?;
            }
        }
        Ok(driver)
    }
}

impl<'rt> FlDriver<'rt> {
    /// Start building a driver over a runtime and experiment config.
    pub fn builder(rt: &'rt Runtime, cfg: ExperimentConfig) -> DriverBuilder<'rt> {
        DriverBuilder {
            rt,
            cfg,
            pipeline: None,
            resume: None,
        }
    }

    fn from_parts(
        rt: &'rt Runtime,
        cfg: ExperimentConfig,
        pipeline: Option<&'rt AePipeline<'rt>>,
    ) -> Result<FlDriver<'rt>> {
        cfg.validate(rt.manifest())?;
        let model = rt.manifest().model(&cfg.model)?.clone();
        let kind = match cfg.model.as_str() {
            "mnist" => SynthKind::Mnist,
            "cifar" => SynthKind::Cifar,
            other => {
                return Err(FedAeError::Config(format!(
                    "no synthetic data family for model `{other}`"
                )))
            }
        };
        if cfg.data.sharding == Sharding::ColorImbalance && kind != SynthKind::Cifar {
            return Err(FedAeError::Config(
                "color_imbalance sharding requires the cifar model".into(),
            ));
        }
        let factory = ShardFactory::new(
            kind,
            cfg.data.sharding,
            cfg.data.alpha,
            cfg.data.per_collab,
            cfg.seed,
        );
        let test = factory.test_set(cfg.data.test_size)?;
        let global = rt.load_init(&format!("{}_params", cfg.model))?;
        let eval = EvalStep::new(rt, &cfg.model)?;
        let network = SimulatedNetwork::from_config(&cfg.network);
        // One live aggregator either way: the sharded adapter wraps the
        // configured algorithm when coordinate sharding is requested.
        let server_agg = if cfg.engine.shard_size > 0 {
            ServerAggregator::Sharded(ShardedAggregator::new(
                cfg.aggregation.clone(),
                cfg.engine.shard_size,
            )?)
        } else {
            ServerAggregator::Plain(crate::aggregation::from_config(&cfg.aggregation)?)
        };
        let engine = ParallelRoundEngine::new(cfg.engine.parallelism);
        let async_engine = AsyncRoundEngine::from_config(&cfg.engine, cfg.seed);
        let log = ExperimentLog::new(cfg.name.clone());

        // AE wiring is checked (and its init loaded) eagerly so a
        // misconfigured experiment fails at build, not at round 0 — the
        // per-client pre-passes themselves run lazily on activation.
        let ae_init = match &cfg.compression {
            CompressionConfig::Ae { ae } => {
                let pipeline = pipeline.ok_or_else(|| {
                    FedAeError::Config("AE compression requires an AePipeline".into())
                })?;
                if &pipeline.tag != ae {
                    return Err(FedAeError::Config(format!(
                        "pipeline is `{}`, config wants `{ae}`",
                        pipeline.tag
                    )));
                }
                Some(rt.load_init(&format!("ae_{ae}_init"))?)
            }
            _ => None,
        };

        let checkpointer = if cfg.checkpoint.enabled() {
            Some(Checkpointer::new(&cfg.checkpoint)?)
        } else {
            None
        };

        let n_clients = cfg.fl.collaborators;
        let sel_seed = cfg.seed ^ SELECTION_SEED_TAG;
        let selector: Box<dyn ClientSelector> = match cfg.selection.policy {
            SelectionPolicy::Uniform => Box::new(UniformSelector::new(sel_seed)),
            // Every synthetic shard holds `per_collab` samples, so
            // sample-count weights are currently uniform; the policy axis
            // exists for heterogeneous shard sizes and draws from its own
            // (exponential-key) stream either way.
            SelectionPolicy::Weighted => Box::new(WeightedSelector::new(
                sel_seed,
                vec![cfg.data.per_collab as f64; n_clients],
            )),
            SelectionPolicy::Stratified => {
                Box::new(StratifiedSelector::new(sel_seed, cfg.selection.strata))
            }
        };

        Ok(FlDriver {
            cfg,
            rt,
            clients: BTreeMap::new(),
            n_clients,
            selector,
            factory,
            pipeline,
            model_n_params: model.n_params,
            init_params: global.clone(),
            ae_init,
            registry: DecoderRegistry::default(),
            shipped: BTreeSet::new(),
            suspended: BTreeMap::new(),
            server_agg,
            engine,
            async_engine,
            checkpointer,
            network,
            eval,
            test,
            global,
            log,
            prepass_results: Vec::new(),
            round: 0,
        })
    }

    /// The experiment configuration this driver was built from.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The current global model parameters.
    pub fn global_params(&self) -> &[f32] {
        &self.global
    }

    /// The compute runtime the driver executes on.
    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    /// Evaluate the global model on the shared test set.
    pub fn eval_global(&self) -> Result<(f32, f32)> {
        self.eval_params(&self.global)
    }

    /// Evaluate arbitrary params on the shared test set.
    pub fn eval_params(&self, params: &[f32]) -> Result<(f32, f32)> {
        let idx: Vec<usize> = (0..self.test.len()).collect();
        let (x, y) = self.test.gather_batch(&idx, self.eval.batch);
        self.eval.eval(params, &x, &y)
    }

    /// Clients currently resident in the lazy state pool.
    pub fn resident_clients(&self) -> usize {
        self.clients.len()
    }

    /// Rounds completed so far — the next round [`FlDriver::run_round`]
    /// will execute, and the round a resumed driver continues from.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Capture every piece of cross-round driver state into a
    /// [`Snapshot`]. Everything *not* captured — client models, batch
    /// streams, compressors, decoders, pre-passes, the selection policy —
    /// is a pure function of `(config, seed)` plus the captured cursors
    /// (roster draw counts, round counter), so
    /// [`FlDriver::restore_from`] rebuilds it bit-identically.
    pub fn snapshot(&self) -> Result<Snapshot> {
        Ok(Snapshot {
            compat: checkpoint::CompatBlock::of(&self.cfg, self.model_n_params),
            round: self.round,
            global: self.global.clone(),
            agg_state: self.server_agg.export_state(),
            async_state: self.async_engine.as_ref().map(|e| checkpoint::AsyncState {
                pending: e.pending().to_vec(),
                totals: e.totals(),
            }),
            roster: self
                .clients
                .iter()
                .map(|(&id, st)| checkpoint::RosterEntry {
                    id,
                    last_used: st.last_used,
                    batches_drawn: st.collaborator.batches_drawn(),
                })
                .collect(),
            suspended: self.suspended.iter().map(|(&id, &d)| (id, d)).collect(),
            shipped: self.shipped.iter().copied().collect(),
            ledger: self.network.ledger().totals(),
        })
    }

    /// Restore a snapshot into a freshly built driver (see
    /// [`DriverBuilder::resume_from`]). Validates the config fingerprint,
    /// installs the explicit state (round counter, global model,
    /// aggregator state, async buffer, ledger totals, shipped set,
    /// suspended cursors), then eagerly re-activates the roster: each
    /// client rebuilds from its seed and fast-forwards its batch stream
    /// to the captured draw count, making it bit-identical to one that
    /// was never torn down. The roster must be rebuilt eagerly — a
    /// buffered late update may apply before its sender is ever
    /// re-selected, and its decoder must already be resident.
    fn restore_from(&mut self, snap: Snapshot) -> Result<()> {
        snap.compat.check(&self.cfg, self.model_n_params)?;
        if snap.global.len() != self.global.len() {
            return Err(FedAeError::Checkpoint(format!(
                "snapshot global model has {} params, model `{}` has {}",
                snap.global.len(),
                self.cfg.model,
                self.global.len()
            )));
        }
        if snap.round > self.cfg.fl.rounds {
            return Err(FedAeError::Checkpoint(format!(
                "snapshot is {} rounds in, config runs only {}",
                snap.round, self.cfg.fl.rounds
            )));
        }
        match (&mut self.async_engine, snap.async_state) {
            (Some(e), Some(a)) => e.restore(a.pending, a.totals),
            (None, None) => {}
            // Unreachable past the compat check (engine mode is part of
            // the fingerprint), kept as a typed corruption guard.
            _ => {
                return Err(FedAeError::Checkpoint(
                    "snapshot async state does not match the engine mode".into(),
                ))
            }
        }
        self.server_agg.import_state(&snap.agg_state)?;
        self.network.restore_ledger(&snap.ledger)?;
        self.global = snap.global;
        self.round = snap.round;
        self.shipped = snap.shipped.iter().copied().collect();
        // Feed every roster entry's draw count through the suspended map
        // so activation fast-forwards each rebuilt batch stream to
        // exactly where the checkpointed one stood.
        self.suspended = snap.suspended.iter().copied().collect();
        for e in &snap.roster {
            self.suspended.insert(e.id, e.batches_drawn);
        }
        let roster_ids: Vec<usize> = snap.roster.iter().map(|e| e.id).collect();
        // `shipped` was restored first, so re-activation re-registers
        // decoders without re-metering shipments or re-recording
        // pre-pass summaries.
        self.activate(snap.round, &roster_ids)?;
        for e in &snap.roster {
            self.clients
                .get_mut(&e.id)
                .expect("roster client just activated")
                .last_used = e.last_used;
        }
        Ok(())
    }

    /// Per-round checkpoint hook: append the round's event record, then
    /// write a snapshot when one is due. The event append comes first, so
    /// a crash between the two leaves the log one round ahead of the
    /// snapshot — resume truncates the log at the snapshot round and the
    /// replay re-appends it, repairing the log to the uninterrupted
    /// bytes.
    fn checkpoint_round(&self, outcome: &RoundOutcome, participants: &[usize]) -> Result<()> {
        if let Some(ck) = &self.checkpointer {
            ck.record_round(&EventRecord {
                round: outcome.round,
                selected: participants.to_vec(),
                admitted: outcome.stragglers.admitted,
                late: outcome.stragglers.late,
                dropped: outcome.stragglers.dropped,
                stale_applied: outcome.stragglers.stale_applied,
                discarded: outcome.selection.discarded,
                eval_loss: outcome.eval_loss,
                eval_acc: outcome.eval_acc,
                mean_recon_mse: outcome.mean_recon_mse,
                bytes_up: outcome.bytes_up,
                bytes_down: outcome.bytes_down,
                full_decodes: outcome.agg.full_decodes,
                range_decodes: outcome.agg.range_decodes,
            })?;
            if ck.snapshot_due(self.round) {
                ck.write_snapshot(&self.snapshot()?)?;
            }
        }
        Ok(())
    }

    /// Resolve this round's targets: `(admit_k, sampled)` where
    /// `admit_k` is the admission target K and `sampled` is the sorted
    /// id set actually drawn (K + slack ids in async over-provisioned
    /// rounds). Pure function of `(seed, round, policy)` — no driver
    /// stream advances.
    fn select_round_participants(&self, round: usize) -> (usize, Vec<usize>) {
        let n = self.n_clients;
        let k = self.cfg.selection.resolve_count(n, self.cfg.fl.participation);
        let sample = self.cfg.selection.sample_size(n, self.cfg.fl.participation);
        (k, self.selector.select(round, n, sample))
    }

    /// Ensure every id in `participants` has resident state, building
    /// what is missing: shard synthesis (and, for the AE scheme, the
    /// pre-pass) fans out across the engine workers; compressor
    /// construction, decoder registration and (first activation only)
    /// the metered decoder shipment happen on this thread in id order.
    /// Every piece is a pure function of `(seed, id)`, so a rebuilt
    /// client is bit-identical to one that was never evicted; the batch
    /// stream continues via the suspended draw count.
    ///
    /// Returns the number of clients newly activated.
    fn activate(&mut self, round: usize, participants: &[usize]) -> Result<usize> {
        let fresh: Vec<usize> = participants
            .iter()
            .copied()
            .filter(|cid| !self.clients.contains_key(cid))
            .collect();
        if fresh.is_empty() {
            return Ok(0);
        }
        let newly = fresh.len();
        let rt = self.rt;
        let factory = &self.factory;
        match &self.cfg.compression {
            CompressionConfig::Ae { ae } => {
                let pipeline = self.pipeline.expect("AE pipeline checked at build");
                let ae_init = self.ae_init.as_ref().expect("AE init loaded at build");
                // Pre-pass (Fig 2) per fresh client, fanned across the
                // engine workers: each task depends only on its own
                // (seed-derived) shard, so parallel execution is
                // deterministic.
                let model_family = self.cfg.model.as_str();
                let prepass_cfg = &self.cfg.prepass;
                let train_cfg = &self.cfg.train;
                let init_params = &self.init_params;
                let base_seed = self.cfg.seed;
                let prepassed: Vec<Result<(usize, Dataset, PrepassResult)>> =
                    self.engine.map(fresh, |id| {
                        let shard = factory.shard(id)?;
                        let pp = run_prepass(
                            rt,
                            model_family,
                            pipeline,
                            &shard,
                            prepass_cfg,
                            train_cfg,
                            init_params,
                            ae_init,
                            base_seed.wrapping_add(id as u64),
                        )?;
                        Ok((id, shard, pp))
                    });
                for item in prepassed {
                    let (id, shard, pp) = item?;
                    self.registry.register(id, pp.dec_params.clone())?;
                    if self.shipped.insert(id) {
                        // First activation: ship the decoder (metered,
                        // Eq. 5 cost) and record the pre-pass. Eviction
                        // models server memory, not the protocol, so a
                        // re-activation re-registers the bit-identical
                        // decoder without re-paying the shipment.
                        let ship = Message::decoder_shipment(
                            id as u32,
                            ae.clone(),
                            pp.dec_params.clone(),
                        );
                        self.network.send(
                            round,
                            id,
                            Direction::Up,
                            TrafficKind::DecoderShipment,
                            ship.wire_bytes(),
                        );
                        self.log.add_summary(
                            format!("prepass_c{id}_final_ae_acc"),
                            pp.ae_history.last().map(|h| h.1).unwrap_or(0.0),
                        );
                        self.prepass_results.push(pp.clone());
                    }
                    let decoder = MeteredDecoder::new(Box::new(AeCompressor::server(
                        pipeline,
                        pp.dec_params.clone(),
                    )?));
                    let comp =
                        Box::new(AeCompressor::collaborator(pipeline, pp.enc_params)?);
                    let mut collaborator = Collaborator::new(
                        rt,
                        &self.cfg.model,
                        id,
                        shard,
                        self.init_params.clone(),
                        comp,
                        self.cfg.seed.wrapping_add(1000 + id as u64),
                    )?;
                    if let Some(drawn) = self.suspended.remove(&id) {
                        collaborator.fast_forward(drawn);
                    }
                    self.clients.insert(
                        id,
                        ClientState {
                            collaborator,
                            decoder,
                            last_used: round,
                        },
                    );
                }
            }
            other => {
                let synthesized: Vec<Result<(usize, Dataset)>> =
                    self.engine.map(fresh, |id| Ok((id, factory.shard(id)?)));
                for item in synthesized {
                    let (id, shard) = item?;
                    let seed = self.cfg.seed.wrapping_mul(31).wrapping_add(id as u64);
                    let comp =
                        crate::compression::from_config(other, self.model_n_params, seed)?;
                    let decomp =
                        crate::compression::from_config(other, self.model_n_params, seed)?;
                    let mut collaborator = Collaborator::new(
                        rt,
                        &self.cfg.model,
                        id,
                        shard,
                        self.init_params.clone(),
                        comp,
                        self.cfg.seed.wrapping_add(1000 + id as u64),
                    )?;
                    if let Some(drawn) = self.suspended.remove(&id) {
                        collaborator.fast_forward(drawn);
                    }
                    self.clients.insert(
                        id,
                        ClientState {
                            collaborator,
                            decoder: MeteredDecoder::new(decomp),
                            last_used: round,
                        },
                    );
                }
            }
        }
        Ok(newly)
    }

    /// Evict least-recently-selected clients beyond
    /// `selection.max_resident`, recording the evicted/resident counts.
    /// Clients with buffered late updates still in flight are pinned:
    /// their decoder must survive until the update's apply round.
    /// Runs after the round's decode meters were drained, so no
    /// accounting is lost.
    fn evict_lru(&mut self, sel_stats: &mut SelectionStats) {
        let max = self.cfg.selection.max_resident;
        if max > 0 && self.clients.len() > max {
            let pinned: BTreeSet<usize> = self
                .async_engine
                .as_ref()
                .map(|e| e.pending_collaborators().collect())
                .unwrap_or_default();
            let mut victims: Vec<(usize, usize)> = self
                .clients
                .iter()
                .filter(|(cid, _)| !pinned.contains(*cid))
                .map(|(&cid, st)| (st.last_used, cid))
                .collect();
            victims.sort_unstable();
            let excess = self.clients.len() - max;
            for &(_, cid) in victims.iter().take(excess) {
                let st = self.clients.remove(&cid).expect("victim is resident");
                self.suspended.insert(cid, st.collaborator.batches_drawn());
                self.registry.unregister(cid);
                sel_stats.evicted += 1;
            }
        }
        sel_stats.resident = self.clients.len();
    }

    /// Whether this round's aggregation runs through the streaming
    /// accumulator path (one full decode per update) or a batch path —
    /// see [`crate::config::AggPath`] for the `auto` policy.
    fn use_streaming_path(&self) -> bool {
        match self.cfg.engine.agg_path {
            AggPath::Batch => false,
            AggPath::Stream => true,
            AggPath::Auto => {
                self.cfg.engine.shard_size == 0 || self.server_agg.supports_streaming()
            }
        }
    }

    /// The streaming-accumulator aggregation path: decode each update
    /// fully **exactly once** (the decode meter asserts this), fold it
    /// into the aggregator's accumulator streams, and drop the
    /// reconstruction before the next decode.
    ///
    /// Unsharded — or sharded with one worker — everything runs on the
    /// coordinator thread: peak memory is the accumulators plus a single
    /// transient reconstruction, independent of the participant count.
    /// Sharded with `engine.parallelism > 1`, the per-shard streams are
    /// chunked contiguously across `std::thread::scope` workers, each
    /// fed every reconstruction through a bounded (capacity-1) channel:
    /// the coordinator still decodes each update once, in update order,
    /// and every shard stream still ingests in that order, so results
    /// are bitwise-identical at any worker count while at most a handful
    /// of reconstructions are in flight.
    ///
    /// Stores the new global model and returns the fresh updates'
    /// reconstruction MSEs (same order and arithmetic as the batch
    /// paths).
    fn aggregate_streaming(
        &mut self,
        updates: &[(usize, u32, CompressedUpdate, usize)],
        decay: f64,
        agg_stats: &mut AggRoundStats,
    ) -> Result<Vec<f32>> {
        let n = self.global.len();
        let m = updates.len();
        let staleness: Vec<usize> = updates.iter().map(|u| u.3).collect();
        let plan = StreamPlan::stale(
            n,
            updates.iter().map(|u| u.1 as f64).collect(),
            &staleness,
            decay,
        )?;
        // Peak model: native streams hold O(n) accumulator state across
        // all shards; buffering adapters (order-sensitive aggregators
        // forced onto this path) hold the whole batch.
        let accum_floats = if self.server_agg.supports_streaming() {
            n
        } else {
            m * n
        };

        // Split the disjoint field borrows once: the accumulator streams
        // borrow `server_agg`, decoding and the MSE bookkeeping borrow
        // the resident client pool.
        let clients = &mut self.clients;

        // Batched decode: when one collaborator contributes several
        // updates this round (async buffering), decode them together via
        // `decompress_batch` — one `[B, latent]` GEMM chain per decoder
        // layer for the AE instead of B gemv passes, bitwise-equal by the
        // batched-decode contract. Results are stashed and consumed at
        // the same positions, so ingest order, MSE bookkeeping and the
        // one-logical-decode-per-update meter invariant are unchanged.
        let mut prefetched: Vec<Option<Vec<f32>>> = Vec::new();
        let mut prefetch_floats = 0u64;
        {
            let mut by_cid: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for (idx, (cid, ..)) in updates.iter().enumerate() {
                by_cid.entry(*cid).or_default().push(idx);
            }
            for (cid, idxs) in by_cid {
                if idxs.len() < 2 {
                    continue;
                }
                if prefetched.is_empty() {
                    prefetched.resize_with(m, || None);
                }
                let st = clients.get_mut(&cid).ok_or_else(|| {
                    FedAeError::Coordination(format!(
                        "no resident state for collaborator {cid}"
                    ))
                })?;
                let batch: Vec<&CompressedUpdate> =
                    idxs.iter().map(|&i| &updates[i].2).collect();
                let outs = st.decoder.decompress_batch(&batch)?;
                prefetch_floats += (outs.len() * n) as u64;
                for (i, out) in idxs.into_iter().zip(outs) {
                    prefetched[i] = Some(out);
                }
            }
        }

        let mut mses: Vec<f32> = Vec::with_capacity(m);
        let mut decode_one = |idx: usize, mses: &mut Vec<f32>| -> Result<Vec<f32>> {
            let (cid, _, update, age) = &updates[idx];
            let st = clients.get_mut(cid).ok_or_else(|| {
                FedAeError::Coordination(format!(
                    "no resident state for collaborator {cid}"
                ))
            })?;
            let recon = match prefetched.get_mut(idx).and_then(Option::take) {
                Some(recon) => recon,
                None => st.decoder.decompress(update)?,
            };
            if recon.len() != n {
                return Err(FedAeError::Coordination(format!(
                    "collaborator {cid}: decode returned {} values, expected {n}",
                    recon.len()
                )));
            }
            if let Err(i) = tensor::check_finite(&recon) {
                return Err(FedAeError::Coordination(format!(
                    "non-finite reconstruction from collaborator {cid} at index {i}"
                )));
            }
            if *age == 0 {
                mses.push(tensor::mse(&recon, st.collaborator.params()) as f32);
            }
            Ok(recon)
        };

        match &mut self.server_agg {
            ServerAggregator::Plain(agg) => {
                agg_stats.peak_floats = (accum_floats + n) as u64 + prefetch_floats;
                let mut stream = agg.begin_stream(&plan)?;
                for i in 0..m {
                    let recon = decode_one(i, &mut mses)?;
                    // Hand the reconstruction over: buffering streams
                    // keep it without a copy, folding streams drop it.
                    stream.ingest_owned(recon)?;
                }
                self.global = stream.finalize()?;
            }
            ServerAggregator::Sharded(sharded) => {
                let mut shard_streams = sharded.begin_shard_streams(&plan)?;
                let workers = self.engine.workers().min(shard_streams.len());
                if workers <= 1 {
                    agg_stats.peak_floats = (accum_floats + n) as u64 + prefetch_floats;
                    let mut new_global = vec![0.0f32; n];
                    for i in 0..m {
                        let recon = decode_one(i, &mut mses)?;
                        for (range, stream) in shard_streams.iter_mut() {
                            stream.ingest(&recon[range.clone()])?;
                        }
                    }
                    for (range, stream) in shard_streams {
                        let piece = stream.finalize()?;
                        if piece.len() != range.len() {
                            return Err(FedAeError::Coordination(format!(
                                "shard {}..{} aggregated to {} values",
                                range.start,
                                range.end,
                                piece.len()
                            )));
                        }
                        new_global[range].copy_from_slice(&piece);
                    }
                    self.global = new_global;
                } else {
                    // Bounded channels keep at most ~3 reconstructions
                    // (the one being distributed plus one queued / one
                    // being ingested, all shared as one Arc) alive at
                    // once, regardless of worker count.
                    agg_stats.peak_floats = (accum_floats + 3 * n) as u64 + prefetch_floats;
                    let chunks = self.engine.chunk(shard_streams);
                    let new_global = std::thread::scope(|scope| -> Result<Vec<f32>> {
                        let mut txs = Vec::with_capacity(chunks.len());
                        let mut handles = Vec::with_capacity(chunks.len());
                        for mut chunk in chunks {
                            let (tx, rx) = mpsc::sync_channel::<Arc<Vec<f32>>>(1);
                            txs.push(tx);
                            handles.push(scope.spawn(
                                move || -> Result<Vec<(std::ops::Range<usize>, Vec<f32>)>> {
                                    for recon in rx.iter() {
                                        for (range, stream) in chunk.iter_mut() {
                                            stream.ingest(&recon[range.clone()])?;
                                        }
                                    }
                                    chunk
                                        .into_iter()
                                        .map(|(range, stream)| {
                                            stream.finalize().map(|piece| (range, piece))
                                        })
                                        .collect()
                                },
                            ));
                        }
                        // Feed: decode each update once, share the Arc
                        // with every worker. A send only fails when that
                        // worker already bailed with an error, which the
                        // join below surfaces; a decode error aborts the
                        // feed and outranks the workers' resulting
                        // under-ingest errors.
                        let mut feed_err = None;
                        for i in 0..m {
                            match decode_one(i, &mut mses) {
                                Ok(recon) => {
                                    let recon = Arc::new(recon);
                                    for tx in &txs {
                                        let _ = tx.send(recon.clone());
                                    }
                                }
                                Err(e) => {
                                    feed_err = Some(e);
                                    break;
                                }
                            }
                        }
                        drop(txs);
                        let mut new_global = vec![0.0f32; n];
                        let mut worker_err = None;
                        for handle in handles {
                            let joined = handle
                                .join()
                                .unwrap_or_else(|payload| std::panic::resume_unwind(payload));
                            match joined {
                                Ok(pieces) => {
                                    for (range, piece) in pieces {
                                        if piece.len() != range.len() {
                                            return Err(FedAeError::Coordination(format!(
                                                "shard {}..{} aggregated to {} values",
                                                range.start,
                                                range.end,
                                                piece.len()
                                            )));
                                        }
                                        new_global[range].copy_from_slice(&piece);
                                    }
                                }
                                Err(e) => {
                                    worker_err.get_or_insert(e);
                                }
                            }
                        }
                        if let Some(e) = feed_err {
                            return Err(e);
                        }
                        if let Some(e) = worker_err {
                            return Err(e);
                        }
                        Ok(new_global)
                    })?;
                    self.global = new_global;
                }
            }
        }
        Ok(mses)
    }

    /// Run one communication round (paper Fig 3).
    ///
    /// Collaborator work (steps 2a–2c) fans out across the
    /// [`ParallelRoundEngine`] workers; everything the server does
    /// (broadcast metering, state machine, aggregation, eval) stays on
    /// this thread. Results are folded back in collaborator-id order, so
    /// the outcome is bitwise-identical for any `parallelism` setting.
    ///
    /// In async mode (`engine.mode = "async"`) the fold additionally
    /// applies the deadline discipline: each upload's seeded simulated
    /// arrival admits it into this round, buffers it for a later round
    /// (staleness-discounted), or drops it — see [`AsyncRoundEngine`].
    pub fn run_round(&mut self) -> Result<RoundOutcome> {
        let round = self.round;
        // 0. Seeded client selection, then lazy activation of whatever
        //    selected state is not yet resident.
        let (admit_k, participants) = self.select_round_participants(round);
        let mut sel_stats = SelectionStats {
            sampled: participants.len(),
            ..SelectionStats::default()
        };
        sel_stats.newly_activated = self.activate(round, &participants)?;
        for &cid in &participants {
            self.clients
                .get_mut(&cid)
                .expect("participant activated")
                .last_used = round;
        }
        let mut state = RoundState::new(round, participants.iter().copied());

        let mut bytes_down = 0u64;
        let mut bytes_up = 0u64;

        // 1. Broadcast the global model.
        let broadcast = Message::GlobalModel {
            round: round as u32,
            params: self.global.clone(),
        };
        for &cid in &participants {
            self.network.send(
                round,
                cid,
                Direction::Down,
                TrafficKind::GlobalModel,
                broadcast.wire_bytes(),
            );
            bytes_down += broadcast.wire_bytes();
            self.clients
                .get_mut(&cid)
                .expect("participant activated")
                .collaborator
                .set_global(&self.global);
        }

        // 2. Local training + local eval + compressed upload, one task
        //    per participant on the engine workers. Workers share the
        //    runtime immutably, own their collaborator mutably, and meter
        //    uploads on private ledgers costed via the shared link.
        let selected: BTreeSet<usize> = participants.iter().copied().collect();
        let link = self.network.link();
        // Async mode: workers evaluate the (Copy, seeded) straggler model
        // themselves; the deadline comparison happens at fold time.
        let straggler: Option<StragglerModel> = self.async_engine.as_ref().map(|e| e.model());
        let eval = &self.eval;
        let local_epochs = self.cfg.fl.local_epochs;
        let train_cfg = &self.cfg.train;
        // The shared test batch, gathered once per round instead of once
        // per collaborator (identical values: the gather is deterministic).
        let test_idx: Vec<usize> = (0..self.test.len()).collect();
        let (test_x, test_y) = self.test.gather_batch(&test_idx, eval.batch);

        let tasks: Vec<(usize, &mut Collaborator<'rt>)> = self
            .clients
            .iter_mut()
            .filter(|(cid, _)| selected.contains(*cid))
            .map(|(&cid, st)| (cid, &mut st.collaborator))
            .collect();
        let results: Vec<Result<CollabRoundResult>> = self.engine.map(tasks, |(cid, collab)| {
            let train_loss = collab.local_train(local_epochs, train_cfg)?;
            // Per-collaborator post-training eval on the shared test
            // set — the paper's Fig 8/9 per-collaborator series.
            let (local_eval_loss, local_eval_acc) =
                eval.eval(collab.params(), &test_x, &test_y)?;
            let update = collab.compressed_update(round)?;
            let msg = Message::encoded_update(
                round as u32,
                cid as u32,
                collab.n_samples() as u32,
                update.to_bytes(),
            );
            let bytes = msg.wire_bytes();
            let base_s = link.transfer_time(bytes);
            // Sync mode: every upload arrives at the uniform link time.
            // Async mode: the seeded straggler model may slow or drop it.
            let fate = match &straggler {
                None => UploadFate::Arrived { arrival_s: base_s },
                Some(model) => model.upload_fate(round, cid, base_s),
            };
            let mut ledger = TrafficLedger::default();
            if let UploadFate::Arrived { arrival_s } = fate {
                ledger.record(Transfer {
                    round,
                    collaborator: cid,
                    direction: Direction::Up,
                    kind: TrafficKind::Update,
                    bytes,
                    sim_seconds: arrival_s,
                });
            }
            Ok(CollabRoundResult {
                cid,
                n_samples: collab.n_samples() as u32,
                train_loss,
                local_eval_loss,
                local_eval_acc,
                update,
                ledger,
                fate,
            })
        });

        // Fold worker results back in collaborator-id order (`map`
        // preserves input order, and tasks were built in id order). In
        // async mode this is where the deadline discipline bites: on-time
        // arrivals are admitted, late ones buffered (bytes already
        // spent), dropped ones discarded entirely. Over-provisioned
        // rounds (`selection.slack > 0`) additionally cap admission at
        // the first K on-time arrivals. Metrics (train loss, local
        // evals) are only recorded for admitted collaborators — a late,
        // dropped or discarded client's eval report never reached the
        // server in time.
        let deadline_s = self.async_engine.as_ref().map(|e| e.deadline_seconds());
        let mut stats = StragglerStats::default();
        let mut train_losses = Vec::with_capacity(participants.len());
        let mut local_evals: Vec<(usize, f32, f32)> = Vec::with_capacity(participants.len());
        let mut on_time: Vec<(f64, CollabRoundResult)> =
            Vec::with_capacity(participants.len());
        for result in results {
            let mut r = result?;
            bytes_up += r.ledger.total_bytes();
            self.network.merge_ledger(std::mem::take(&mut r.ledger));
            match r.fate {
                UploadFate::Dropped => {
                    stats.dropped += 1;
                }
                UploadFate::Arrived { arrival_s } => match deadline_s {
                    Some(d) if arrival_s > d => {
                        stats.late += 1;
                        self.async_engine
                            .as_mut()
                            .expect("deadline implies async engine")
                            .buffer_late(round, r.cid, r.n_samples, r.update, arrival_s);
                    }
                    _ => on_time.push((arrival_s, r)),
                },
            }
        }
        // Over-provisioned admission: the server stops listening after
        // the K-th on-time arrival (ordered by arrival time, ties by
        // id); later on-time uploads are discarded — their bytes were
        // still spent. With `slack = 0` at most K clients were sampled,
        // so the cap never binds and admission matches the plain fold
        // exactly.
        if on_time.len() > admit_k {
            on_time.sort_by(|a, b| {
                a.0.partial_cmp(&b.0)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.1.cid.cmp(&b.1.cid))
            });
            sel_stats.discarded = on_time.len() - admit_k;
            on_time.truncate(admit_k);
            on_time.sort_by_key(|(_, r)| r.cid);
        }
        for (arrival_s, r) in on_time {
            stats.admitted += 1;
            stats.sim_round_seconds = stats.sim_round_seconds.max(arrival_s);
            train_losses.push((r.cid, r.train_loss));
            local_evals.push((r.cid, r.local_eval_loss, r.local_eval_acc));
            state.accept(round, r.cid, r.n_samples, r.update)?;
        }
        match deadline_s {
            // Sync mode keeps the paper's barrier invariant.
            None => {
                if !state.is_complete() {
                    return Err(FedAeError::Coordination(format!(
                        "round {round} incomplete: missing {:?}",
                        state.missing()
                    )));
                }
            }
            // A deadline-paced round closes at the deadline whenever
            // anything was late or dropped; when over-provisioned
            // admission filled instead, it closes at the K-th arrival
            // (already the running max over admitted); otherwise at the
            // last arrival.
            Some(d) => {
                if sel_stats.discarded == 0 && stats.late + stats.dropped > 0 && d.is_finite() {
                    stats.sim_round_seconds = d;
                }
            }
        }

        // 3. Server-side reconstruction + aggregation. Three execution
        //    paths, all bitwise-identical for a fixed seed
        //    (rust/tests/streaming_agg.rs):
        //    * streaming (default for unsharded rounds and for the
        //      linear aggregators under sharding): each update is fully
        //      decoded exactly ONCE and folded straight into the
        //      aggregator's accumulator streams — per shard when
        //      sharded, fanned across scoped-thread workers when
        //      `parallelism > 1`;
        //    * shard-major batch (order-sensitive aggregators under
        //      sharding): coordinate ranges stream through
        //      `decompress_range`, bounding peak memory at
        //      participants x shard_size;
        //    * materialized batch (`agg_path = "batch"`, unsharded):
        //      every reconstruction at once, then one aggregate call.
        //    Async mode appends the buffered late updates due this
        //    round, tagged by staleness; every path applies the same
        //    `α/(s+1)` weight discount (a x1.0 no-op when everything is
        //    fresh and decay is 1.0, which is what keeps sync results
        //    bitwise-unchanged).
        let decay = self
            .async_engine
            .as_ref()
            .map(|e| e.staleness_decay())
            .unwrap_or(1.0);
        // (cid, n_samples, update, staleness): fresh admitted updates in
        // collaborator-id order, then due buffered updates in buffering
        // order — a deterministic operand order either way.
        let mut updates: Vec<(usize, u32, CompressedUpdate, usize)> = state
            .take_updates()
            .into_iter()
            .map(|(c, s, u)| (c, s, u, 0usize))
            .collect();
        if let Some(engine) = &mut self.async_engine {
            for b in engine.drain_due(round) {
                let staleness = round - b.origin_round;
                stats.stale_applied += 1;
                stats.max_staleness = stats.max_staleness.max(staleness);
                updates.push((b.collaborator, b.n_samples, b.update, staleness));
            }
        }
        let shard_size = self.cfg.engine.shard_size;
        let agg_sw = Stopwatch::start();
        let mut agg_stats = AggRoundStats::default();
        let recon_mses: Vec<f32> = if updates.is_empty() {
            // Every upload was late or dropped (async only): the global
            // model carries over unchanged this round.
            Vec::new()
        } else if self.use_streaming_path() {
            self.aggregate_streaming(&updates, decay, &mut agg_stats)?
        } else if shard_size > 0 {
            let n = self.global.len();
            let m = updates.len();
            // Peak model: every update's slice of the current shard,
            // plus one transient full reconstruction per range call for
            // schemes without random access (AE decoder, sketch).
            let full_range = updates
                .iter()
                .any(|(cid, ..)| self.clients[cid].decoder.range_decode_is_full());
            agg_stats.peak_floats =
                (m * shard_size.min(n) + if full_range { n } else { 0 }) as u64;
            let mut new_global = vec![0.0f32; n];
            let staleness: Vec<usize> = updates.iter().map(|u| u.3).collect();
            // Update indices grouped by sender: each sender's metered
            // decoder is a disjoint `&mut` inside the client pool, so an
            // engine worker can own one sender's decoder for a whole
            // shard's decodes while other workers decode other senders'
            // ranges concurrently.
            let mut by_cid: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for (i, (cid, ..)) in updates.iter().enumerate() {
                by_cid.entry(*cid).or_default().push(i);
            }
            // Reconstruction error accumulators, one per update, built up
            // shard-by-shard in the same coordinate order as the
            // unsharded `tensor::mse` (f64 accumulation, so the final
            // mean matches bitwise). Only fresh updates contribute: a
            // stale update's sender has trained on since, so comparing
            // against its *current* local params would be meaningless.
            let mut sq_err = vec![0.0f64; m];
            for (s, range) in shard_ranges(n, shard_size).enumerate() {
                // Decode pass, fanned across the engine workers grouped
                // by sender. Every range decode is a pure function of
                // (decoder, update, range) — no decoder carries state
                // across calls — so any fan-out order reproduces the
                // sequential walk bitwise (rust/tests/streaming_agg.rs
                // pins the equivalence, decode counts included).
                let updates_ref = &updates;
                let range_ref = &range;
                let decode_tasks: Vec<(&Vec<usize>, &mut MeteredDecoder<'rt>)> = self
                    .clients
                    .iter_mut()
                    .filter_map(|(cid, st)| by_cid.get(cid).map(|idxs| (idxs, &mut st.decoder)))
                    .collect();
                let decoded: Vec<Result<Vec<(usize, Vec<f32>)>>> =
                    self.engine.map(decode_tasks, |(idxs, decoder)| {
                        idxs.iter()
                            .map(|&i| {
                                let (_, _, update, _) = &updates_ref[i];
                                let piece =
                                    decoder.decompress_range(update, range_ref.clone())?;
                                Ok((i, piece))
                            })
                            .collect()
                    });
                let mut pieces: Vec<Option<Vec<f32>>> = (0..m).map(|_| None).collect();
                for group in decoded {
                    for (i, piece) in group? {
                        pieces[i] = Some(piece);
                    }
                }
                // Check + MSE + aggregation pass, sequential in the
                // original update order so operand order (and therefore
                // every float) matches the pre-parallel path bitwise.
                let mut shard_updates = Vec::with_capacity(m);
                for (i, (cid, n_samples, _, age)) in updates.iter().enumerate() {
                    let piece = pieces[i].take().ok_or_else(|| {
                        FedAeError::Coordination(format!(
                            "no resident state for collaborator {cid}"
                        ))
                    })?;
                    if piece.len() != range.len() {
                        return Err(FedAeError::Coordination(format!(
                            "collaborator {cid}: shard decode returned {} values for {}..{}",
                            piece.len(),
                            range.start,
                            range.end
                        )));
                    }
                    if let Err(j) = tensor::check_finite(&piece) {
                        return Err(FedAeError::Coordination(format!(
                            "non-finite reconstruction from collaborator {cid} at index {}",
                            range.start + j
                        )));
                    }
                    if *age == 0 {
                        let local = self.clients[cid].collaborator.params();
                        for (k, &v) in piece.iter().enumerate() {
                            let d = (v - local[range.start + k]) as f64;
                            sq_err[i] += d * d;
                        }
                    }
                    shard_updates.push(WeightedUpdate {
                        weight: *n_samples as f64,
                        values: piece,
                    });
                }
                let piece = self.server_agg.as_aggregator().aggregate_shard_stale(
                    s,
                    shard_updates,
                    &staleness,
                    decay,
                )?;
                if piece.len() != range.len() {
                    return Err(FedAeError::Coordination(format!(
                        "shard {s} aggregated to {} values, expected {}",
                        piece.len(),
                        range.len()
                    )));
                }
                new_global[range].copy_from_slice(&piece);
            }
            self.global = new_global;
            updates
                .iter()
                .zip(&sq_err)
                .filter(|(u, _)| u.3 == 0)
                .map(|(_, &e)| (e / n as f64) as f32)
                .collect()
        } else {
            agg_stats.peak_floats = (updates.len() * self.global.len()) as u64;
            let mut weighted = Vec::with_capacity(updates.len());
            let mut staleness = Vec::with_capacity(updates.len());
            let mut mses = Vec::with_capacity(updates.len());
            for (cid, n_samples, update, age) in updates {
                let st = self.clients.get_mut(&cid).ok_or_else(|| {
                    FedAeError::Coordination(format!(
                        "no resident state for collaborator {cid}"
                    ))
                })?;
                let recon = st.decoder.decompress(&update)?;
                if let Err(i) = tensor::check_finite(&recon) {
                    return Err(FedAeError::Coordination(format!(
                        "non-finite reconstruction from collaborator {cid} at index {i}"
                    )));
                }
                if age == 0 {
                    mses.push(tensor::mse(&recon, st.collaborator.params()) as f32);
                }
                staleness.push(age);
                weighted.push(WeightedUpdate {
                    weight: n_samples as f64,
                    values: recon,
                });
            }
            self.global = self
                .server_agg
                .as_aggregator()
                .aggregate_stale(weighted, &staleness, decay)?;
            mses
        };
        for st in self.clients.values_mut() {
            let s = st.decoder.take_stats();
            agg_stats.full_decodes += s.full_decodes;
            agg_stats.range_decodes += s.range_decodes;
            agg_stats.batched_decodes += s.batched_decodes;
            agg_stats.decoded_floats += s.decoded_floats;
        }
        agg_stats.ms = agg_sw.elapsed_ms();

        // 4. Evaluate the new global model (on the batch already gathered
        //    for the per-collaborator evals — identical values).
        let (eval_loss, eval_acc) = self.eval.eval(&self.global, &test_x, &test_y)?;

        let mean_recon_mse = if recon_mses.is_empty() {
            f32::NAN
        } else {
            recon_mses.iter().sum::<f32>() / recon_mses.len() as f32
        };

        // Record per-collaborator metrics.
        for (&(cid, train_loss), &(_, local_eval_loss, local_eval_acc)) in
            train_losses.iter().zip(&local_evals)
        {
            self.log.push(RoundRecord {
                round,
                collaborator: cid,
                train_loss,
                eval_loss,
                eval_acc,
                local_eval_loss,
                local_eval_acc,
                bytes_up: bytes_up / participants.len() as u64,
                bytes_down: bytes_down / participants.len() as u64,
                recon_mse: mean_recon_mse,
            });
        }

        // 5. Evict resident state beyond `selection.max_resident` —
        //    after the decode meters were drained, and pinning clients
        //    whose buffered late updates are still in flight.
        self.evict_lru(&mut sel_stats);

        if let Some(engine) = &mut self.async_engine {
            engine.record_round(&stats);
        }
        self.round += 1;
        let outcome = RoundOutcome {
            round,
            train_losses,
            eval_loss,
            eval_acc,
            mean_recon_mse,
            bytes_up,
            bytes_down,
            stragglers: stats,
            agg: agg_stats,
            selection: sel_stats,
        };

        // 6. Checkpointing (when configured): event record every round,
        //    snapshot every `checkpoint.every_rounds`.
        self.checkpoint_round(&outcome, &participants)?;
        Ok(outcome)
    }

    /// Cumulative async-mode straggler accounting (`None` in sync mode).
    pub fn async_totals(&self) -> Option<StragglerStats> {
        self.async_engine.as_ref().map(|e| e.totals())
    }

    /// Late updates currently buffered and not yet applied (0 in sync
    /// mode).
    pub fn async_pending(&self) -> usize {
        self.async_engine
            .as_ref()
            .map(|e| e.pending_len())
            .unwrap_or(0)
    }

    /// Run the remaining configured rounds (all of them on a fresh
    /// driver, rounds `snapshot.round..fl.rounds` after a resume);
    /// returns the final outcome.
    pub fn run(&mut self) -> Result<RoundOutcome> {
        let mut last = None;
        let mut agg_totals = AggRoundStats::default();
        let mut sel_activated = 0usize;
        let mut sel_evicted = 0usize;
        let mut sel_discarded = 0usize;
        for _ in self.round..self.cfg.fl.rounds {
            let outcome = self.run_round()?;
            agg_totals.accumulate(&outcome.agg);
            sel_activated += outcome.selection.newly_activated;
            sel_evicted += outcome.selection.evicted;
            sel_discarded += outcome.selection.discarded;
            last = Some(outcome);
        }
        let outcome = last.ok_or_else(|| FedAeError::Config("zero rounds".into()))?;
        let model = self.rt.manifest().model(&self.cfg.model)?;
        let raw_bytes = (model.n_params * 4) as u64;
        if let Some(ratio) = self.network.ledger().measured_update_ratio(raw_bytes) {
            self.log.add_summary("measured_update_ratio", format!("{ratio:.1}"));
        }
        self.log.add_summary(
            "total_bytes_up_updates",
            self.network.ledger().update_bytes_up(),
        );
        self.log.add_summary(
            "decoder_shipment_bytes",
            self.network
                .ledger()
                .bytes_for(Direction::Up, TrafficKind::DecoderShipment),
        );
        self.log
            .add_summary("final_eval_acc", format!("{:.4}", outcome.eval_acc));
        // Server aggregation cost accounting (one source of truth with
        // the per-round `agg_*` log fields and the streaming-agg bench).
        self.log
            .add_summary("agg_full_decodes_total", agg_totals.full_decodes);
        self.log
            .add_summary("agg_range_decodes_total", agg_totals.range_decodes);
        self.log
            .add_summary("agg_batched_decodes_total", agg_totals.batched_decodes);
        self.log
            .add_summary("agg_decoded_floats_total", agg_totals.decoded_floats);
        self.log
            .add_summary("agg_peak_floats_max", agg_totals.peak_floats);
        self.log
            .add_summary("agg_ms_total", format!("{:.3}", agg_totals.ms));
        // Client-selection / resident-pool accounting.
        self.log
            .add_summary("selection_policy", self.selector.name());
        self.log
            .add_summary("selection_activated_total", sel_activated);
        self.log.add_summary("selection_evicted_total", sel_evicted);
        self.log
            .add_summary("selection_discarded_total", sel_discarded);
        self.log
            .add_summary("resident_clients_end", self.clients.len());
        if let Some(engine) = &self.async_engine {
            let t = engine.totals();
            self.log.add_summary("async_admitted_total", t.admitted);
            self.log.add_summary("async_late_total", t.late);
            self.log.add_summary("async_dropped_total", t.dropped);
            self.log
                .add_summary("async_stale_applied_total", t.stale_applied);
            self.log.add_summary("async_max_staleness", t.max_staleness);
            self.log
                .add_summary("async_pending_end", engine.pending_len());
            self.log.add_summary(
                "async_sim_seconds_total",
                format!("{:.3}", t.sim_round_seconds),
            );
        }
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd() -> CompressedUpdate {
        CompressedUpdate::Raw {
            values: vec![1.0, 2.0],
        }
    }

    #[test]
    fn round_state_accepts_expected() {
        let mut s = RoundState::new(3, [0, 1, 2]);
        s.accept(3, 1, 10, upd()).unwrap();
        assert!(!s.is_complete());
        assert_eq!(s.missing(), vec![0, 2]);
        s.accept(3, 0, 5, upd()).unwrap();
        s.accept(3, 2, 7, upd()).unwrap();
        assert!(s.is_complete());
        let updates = s.take_updates();
        assert_eq!(updates.len(), 3);
        assert_eq!(updates[0].0, 0); // ordered by collaborator
        assert_eq!(updates[1].1, 10);
    }

    #[test]
    fn round_state_rejects_stale_round() {
        let mut s = RoundState::new(5, [0]);
        let err = s.accept(4, 0, 1, upd()).unwrap_err();
        assert!(err.to_string().contains("stale"));
        assert!(s.accept(6, 0, 1, upd()).is_err());
    }

    #[test]
    fn round_state_rejects_duplicate() {
        let mut s = RoundState::new(0, [0, 1]);
        s.accept(0, 0, 1, upd()).unwrap();
        let err = s.accept(0, 0, 1, upd()).unwrap_err();
        assert!(err.to_string().contains("duplicate"));
    }

    #[test]
    fn round_state_rejects_unknown_collaborator() {
        let mut s = RoundState::new(0, [0, 1]);
        let err = s.accept(0, 9, 1, upd()).unwrap_err();
        assert!(err.to_string().contains("unknown"));
    }

    #[test]
    fn decoder_registry_single_registration() {
        let reg = DecoderRegistry::default();
        assert!(reg.is_empty());
        reg.register(0, vec![1.0]).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get(0).unwrap().as_slice(), &[1.0]);
        assert!(reg.register(0, vec![2.0]).is_err());
        assert!(reg.get(1).is_err());
    }

    #[test]
    fn decoder_registry_concurrent_registration() {
        let reg = DecoderRegistry::default();
        std::thread::scope(|s| {
            for worker in 0..4 {
                let reg = &reg;
                s.spawn(move || {
                    for id in (worker..16).step_by(4) {
                        reg.register(id, vec![id as f32]).unwrap();
                    }
                });
            }
        });
        assert_eq!(reg.len(), 16);
        for id in 0..16 {
            assert_eq!(reg.get(id).unwrap().as_slice(), &[id as f32]);
        }
    }
}
