//! Aggregator/coordinator: the server side of the federation.
//!
//! * [`RoundState`] — per-round state machine accepting updates with
//!   duplicate / stale / unknown-collaborator protection.
//! * [`DecoderRegistry`] — decoders shipped at the end of the pre-pass
//!   round, keyed by collaborator (paper §5.3 case (b)) or shared
//!   (case (a)).
//! * [`FlDriver`] — the in-process experiment driver: wires collaborators,
//!   compressors, aggregation, the simulated network and metrics into the
//!   paper's federated loop (Fig 3), including the pre-pass round (Fig 2).

use std::collections::{BTreeMap, BTreeSet};

use crate::aggregation::{Aggregator, WeightedUpdate};
use crate::collaborator::{run_prepass, Collaborator, PrepassResult};
use crate::compression::{ae::AeCompressor, CompressedUpdate, UpdateCompressor};
use crate::config::{CompressionConfig, ExperimentConfig, Sharding};
use crate::data::{make_shards, Dataset, SynthKind};
use crate::error::{FedAeError, Result};
use crate::metrics::{ExperimentLog, RoundRecord};
use crate::network::{Direction, SimulatedNetwork, TrafficKind};
use crate::runtime::{AePipeline, EvalStep, Runtime};
use crate::tensor;
use crate::transport::Message;

/// Per-round server state machine.
#[derive(Debug)]
pub struct RoundState {
    pub round: usize,
    expected: BTreeSet<usize>,
    received: BTreeMap<usize, (u32, CompressedUpdate)>,
}

impl RoundState {
    pub fn new(round: usize, expected: impl IntoIterator<Item = usize>) -> RoundState {
        RoundState {
            round,
            expected: expected.into_iter().collect(),
            received: BTreeMap::new(),
        }
    }

    /// Accept one update; enforces protocol invariants.
    pub fn accept(
        &mut self,
        round: usize,
        collab: usize,
        n_samples: u32,
        update: CompressedUpdate,
    ) -> Result<()> {
        if round != self.round {
            return Err(FedAeError::Coordination(format!(
                "stale/early update: got round {round}, current {}",
                self.round
            )));
        }
        if !self.expected.contains(&collab) {
            return Err(FedAeError::Coordination(format!(
                "unknown or unselected collaborator {collab} for round {round}"
            )));
        }
        if self.received.contains_key(&collab) {
            return Err(FedAeError::Coordination(format!(
                "duplicate update from collaborator {collab} in round {round}"
            )));
        }
        self.received.insert(collab, (n_samples, update));
        Ok(())
    }

    pub fn is_complete(&self) -> bool {
        self.received.len() == self.expected.len()
    }

    pub fn received_count(&self) -> usize {
        self.received.len()
    }

    pub fn missing(&self) -> Vec<usize> {
        self.expected
            .iter()
            .filter(|c| !self.received.contains_key(c))
            .copied()
            .collect()
    }

    /// Drain the received updates (ordered by collaborator id).
    pub fn take_updates(self) -> Vec<(usize, u32, CompressedUpdate)> {
        self.received
            .into_iter()
            .map(|(c, (n, u))| (c, n, u))
            .collect()
    }
}

/// Decoders shipped to the server at the end of the pre-pass round.
#[derive(Debug, Default)]
pub struct DecoderRegistry {
    decoders: BTreeMap<usize, Vec<f32>>,
}

impl DecoderRegistry {
    pub fn register(&mut self, collab: usize, dec_params: Vec<f32>) -> Result<()> {
        if self.decoders.contains_key(&collab) {
            return Err(FedAeError::Coordination(format!(
                "decoder already registered for collaborator {collab}"
            )));
        }
        self.decoders.insert(collab, dec_params);
        Ok(())
    }

    pub fn get(&self, collab: usize) -> Result<&[f32]> {
        self.decoders
            .get(&collab)
            .map(|v| v.as_slice())
            .ok_or_else(|| {
                FedAeError::Coordination(format!(
                    "no decoder registered for collaborator {collab}"
                ))
            })
    }

    pub fn len(&self) -> usize {
        self.decoders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.decoders.is_empty()
    }
}

/// Outcome of one communication round.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    pub round: usize,
    /// (collaborator, local train loss).
    pub train_losses: Vec<(usize, f32)>,
    /// Post-aggregation global eval.
    pub eval_loss: f32,
    pub eval_acc: f32,
    /// Mean reconstruction MSE across updates (NaN for lossless).
    pub mean_recon_mse: f32,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

/// The whole-experiment driver (single-process simulation).
pub struct FlDriver<'rt> {
    cfg: ExperimentConfig,
    rt: &'rt Runtime,
    collaborators: Vec<Collaborator<'rt>>,
    /// Server-side decompressors, one per collaborator.
    server_decompressors: Vec<Box<dyn UpdateCompressor + 'rt>>,
    aggregator: Box<dyn Aggregator>,
    pub network: SimulatedNetwork,
    eval: EvalStep<'rt>,
    test: Dataset,
    global: Vec<f32>,
    pub log: ExperimentLog,
    rng: crate::util::rng::Rng,
    /// Pre-pass results per collaborator (kept for figures/validation).
    pub prepass_results: Vec<PrepassResult>,
    round: usize,
}

impl<'rt> std::fmt::Debug for FlDriver<'rt> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlDriver")
            .field("experiment", &self.cfg.name)
            .field("collaborators", &self.collaborators.len())
            .field("round", &self.round)
            .finish()
    }
}

impl<'rt> FlDriver<'rt> {
    /// Build the full experiment from config: shards, collaborators,
    /// compressors (running the pre-pass round when the AE scheme is
    /// selected), aggregation and the simulated network.
    ///
    /// `pipeline` must be provided when `cfg.compression` is `Ae`.
    pub fn new(
        rt: &'rt Runtime,
        cfg: ExperimentConfig,
        pipeline: Option<&'rt AePipeline<'rt>>,
    ) -> Result<FlDriver<'rt>> {
        cfg.validate(rt.manifest())?;
        let model = rt.manifest().model(&cfg.model)?.clone();
        let kind = match cfg.model.as_str() {
            "mnist" => SynthKind::Mnist,
            "cifar" => SynthKind::Cifar,
            other => {
                return Err(FedAeError::Config(format!(
                    "no synthetic data family for model `{other}`"
                )))
            }
        };
        if cfg.data.sharding == Sharding::ColorImbalance && kind != SynthKind::Cifar {
            return Err(FedAeError::Config(
                "color_imbalance sharding requires the cifar model".into(),
            ));
        }
        let (shards, test) = make_shards(
            kind,
            cfg.data.sharding,
            cfg.data.alpha,
            cfg.fl.collaborators,
            cfg.data.per_collab,
            cfg.data.test_size,
            cfg.seed,
        )?;
        let global = rt.load_init(&format!("{}_params", cfg.model))?;
        let eval = EvalStep::new(rt, &cfg.model)?;
        let mut network = SimulatedNetwork::from_config(&cfg.network);
        let aggregator = crate::aggregation::from_config(&cfg.aggregation)?;
        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let mut log = ExperimentLog::new(cfg.name.clone());

        // Build compressors (+ pre-pass when using the AE scheme).
        let mut collaborators = Vec::with_capacity(cfg.fl.collaborators);
        let mut server_decompressors: Vec<Box<dyn UpdateCompressor + 'rt>> = Vec::new();
        let mut prepass_results = Vec::new();

        match &cfg.compression {
            CompressionConfig::Ae { ae } => {
                let pipeline = pipeline.ok_or_else(|| {
                    FedAeError::Config("AE compression requires an AePipeline".into())
                })?;
                if &pipeline.tag != ae {
                    return Err(FedAeError::Config(format!(
                        "pipeline is `{}`, config wants `{ae}`",
                        pipeline.tag
                    )));
                }
                let ae_init = rt.load_init(&format!("ae_{ae}_init"))?;
                let mut registry = DecoderRegistry::default();
                for (id, shard) in shards.into_iter().enumerate() {
                    // Pre-pass (Fig 2): local training + AE training.
                    let pp = run_prepass(
                        rt,
                        &cfg.model,
                        pipeline,
                        &shard,
                        &cfg.prepass,
                        &cfg.train,
                        &global,
                        &ae_init,
                        cfg.seed.wrapping_add(id as u64),
                    )?;
                    // Ship the decoder (metered, Eq. 5 cost).
                    let ship = Message::DecoderShipment {
                        collab_id: id as u32,
                        ae_tag: ae.clone(),
                        dec_params: pp.dec_params.clone(),
                    };
                    network.send(
                        0,
                        id,
                        Direction::Up,
                        TrafficKind::DecoderShipment,
                        ship.wire_bytes(),
                    );
                    registry.register(id, pp.dec_params.clone())?;
                    server_decompressors
                        .push(Box::new(AeCompressor::server(pipeline, pp.dec_params.clone())?));
                    let comp =
                        Box::new(AeCompressor::collaborator(pipeline, pp.enc_params.clone())?);
                    collaborators.push(Collaborator::new(
                        rt,
                        &cfg.model,
                        id,
                        shard,
                        global.clone(),
                        comp,
                        cfg.seed.wrapping_add(1000 + id as u64),
                    )?);
                    log.add_summary(
                        format!("prepass_c{id}_final_ae_acc"),
                        pp.ae_history.last().map(|h| h.1).unwrap_or(0.0),
                    );
                    prepass_results.push(pp);
                }
            }
            other => {
                for (id, shard) in shards.into_iter().enumerate() {
                    let seed = cfg.seed.wrapping_mul(31).wrapping_add(id as u64);
                    let comp = crate::compression::from_config(other, model.n_params, seed)?;
                    let decomp = crate::compression::from_config(other, model.n_params, seed)?;
                    server_decompressors.push(decomp);
                    collaborators.push(Collaborator::new(
                        rt,
                        &cfg.model,
                        id,
                        shard,
                        global.clone(),
                        comp,
                        cfg.seed.wrapping_add(1000 + id as u64),
                    )?);
                }
            }
        }

        let _ = rng.next_u64(); // decorrelate selection stream from sharding
        Ok(FlDriver {
            cfg,
            rt,
            collaborators,
            server_decompressors,
            aggregator,
            network,
            eval,
            test,
            global,
            log,
            rng,
            prepass_results,
            round: 0,
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn global_params(&self) -> &[f32] {
        &self.global
    }

    pub fn runtime(&self) -> &'rt Runtime {
        self.rt
    }

    /// Evaluate the global model on the shared test set.
    pub fn eval_global(&self) -> Result<(f32, f32)> {
        self.eval_params(&self.global)
    }

    /// Evaluate arbitrary params on the shared test set.
    pub fn eval_params(&self, params: &[f32]) -> Result<(f32, f32)> {
        let idx: Vec<usize> = (0..self.test.len()).collect();
        let (x, y) = self.test.gather_batch(&idx, self.eval.batch);
        self.eval.eval(params, &x, &y)
    }

    /// Client selection for a round (participation sampling).
    fn select_round_participants(&mut self) -> Vec<usize> {
        let n = self.collaborators.len();
        let k = ((n as f64 * self.cfg.fl.participation).round() as usize).clamp(1, n);
        if k == n {
            (0..n).collect()
        } else {
            let mut sel = self.rng.sample_indices(n, k);
            sel.sort_unstable();
            sel
        }
    }

    /// Run one communication round (paper Fig 3).
    pub fn run_round(&mut self) -> Result<RoundOutcome> {
        let round = self.round;
        let participants = self.select_round_participants();
        let mut state = RoundState::new(round, participants.iter().copied());

        let mut bytes_down = 0u64;
        let mut bytes_up = 0u64;
        let mut train_losses = Vec::with_capacity(participants.len());

        // 1. Broadcast the global model.
        let broadcast = Message::GlobalModel {
            round: round as u32,
            params: self.global.clone(),
        };
        for &cid in &participants {
            self.network.send(
                round,
                cid,
                Direction::Down,
                TrafficKind::GlobalModel,
                broadcast.wire_bytes(),
            );
            bytes_down += broadcast.wire_bytes();
            self.collaborators[cid].set_global(&self.global);
        }

        // 2. Local training + compressed upload.
        let mut local_evals: Vec<(usize, f32, f32)> = Vec::with_capacity(participants.len());
        for &cid in &participants {
            let loss =
                self.collaborators[cid].local_train(self.cfg.fl.local_epochs, &self.cfg.train)?;
            train_losses.push((cid, loss));
            // Per-collaborator post-training eval on the shared test set —
            // the paper's Fig 8/9 per-collaborator series.
            let (ll, la) = self.eval_params(self.collaborators[cid].params())?;
            local_evals.push((cid, ll, la));
            let update = self.collaborators[cid].compressed_update(round)?;
            let msg = Message::EncodedUpdate {
                round: round as u32,
                collab_id: cid as u32,
                n_samples: self.collaborators[cid].n_samples() as u32,
                payload: update.to_bytes(),
            };
            bytes_up += msg.wire_bytes();
            self.network.send(
                round,
                cid,
                Direction::Up,
                TrafficKind::Update,
                msg.wire_bytes(),
            );
            state.accept(
                round,
                cid,
                self.collaborators[cid].n_samples() as u32,
                update,
            )?;
        }
        if !state.is_complete() {
            return Err(FedAeError::Coordination(format!(
                "round {round} incomplete: missing {:?}",
                state.missing()
            )));
        }

        // 3. Server-side reconstruction + aggregation.
        let mut weighted = Vec::with_capacity(participants.len());
        let mut recon_mses = Vec::new();
        for (cid, n_samples, update) in state.take_updates() {
            let recon = self.server_decompressors[cid].decompress(&update)?;
            if let Err(i) = tensor::check_finite(&recon) {
                return Err(FedAeError::Coordination(format!(
                    "non-finite reconstruction from collaborator {cid} at index {i}"
                )));
            }
            recon_mses.push(tensor::mse(&recon, self.collaborators[cid].params()) as f32);
            weighted.push(WeightedUpdate {
                weight: n_samples as f64,
                values: recon,
            });
        }
        self.global = self.aggregator.aggregate(&weighted)?;

        // 4. Evaluate the new global model.
        let (eval_loss, eval_acc) = self.eval_global()?;

        let mean_recon_mse = if recon_mses.is_empty() {
            f32::NAN
        } else {
            recon_mses.iter().sum::<f32>() / recon_mses.len() as f32
        };

        // Record per-collaborator metrics.
        for (&(cid, train_loss), &(_, local_eval_loss, local_eval_acc)) in
            train_losses.iter().zip(&local_evals)
        {
            self.log.push(RoundRecord {
                round,
                collaborator: cid,
                train_loss,
                eval_loss,
                eval_acc,
                local_eval_loss,
                local_eval_acc,
                bytes_up: bytes_up / participants.len() as u64,
                bytes_down: bytes_down / participants.len() as u64,
                recon_mse: mean_recon_mse,
            });
        }

        self.round += 1;
        Ok(RoundOutcome {
            round,
            train_losses,
            eval_loss,
            eval_acc,
            mean_recon_mse,
            bytes_up,
            bytes_down,
        })
    }

    /// Run the configured number of rounds; returns the final outcome.
    pub fn run(&mut self) -> Result<RoundOutcome> {
        let mut last = None;
        for _ in 0..self.cfg.fl.rounds {
            last = Some(self.run_round()?);
        }
        let outcome = last.ok_or_else(|| FedAeError::Config("zero rounds".into()))?;
        let model = self.rt.manifest().model(&self.cfg.model)?;
        let raw_bytes = (model.n_params * 4) as u64;
        if let Some(ratio) = self.network.ledger().measured_update_ratio(raw_bytes) {
            self.log.add_summary("measured_update_ratio", format!("{ratio:.1}"));
        }
        self.log.add_summary(
            "total_bytes_up_updates",
            self.network.ledger().update_bytes_up(),
        );
        self.log.add_summary(
            "decoder_shipment_bytes",
            self.network
                .ledger()
                .bytes_for(Direction::Up, TrafficKind::DecoderShipment),
        );
        self.log
            .add_summary("final_eval_acc", format!("{:.4}", outcome.eval_acc));
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd() -> CompressedUpdate {
        CompressedUpdate::Raw {
            values: vec![1.0, 2.0],
        }
    }

    #[test]
    fn round_state_accepts_expected() {
        let mut s = RoundState::new(3, [0, 1, 2]);
        s.accept(3, 1, 10, upd()).unwrap();
        assert!(!s.is_complete());
        assert_eq!(s.missing(), vec![0, 2]);
        s.accept(3, 0, 5, upd()).unwrap();
        s.accept(3, 2, 7, upd()).unwrap();
        assert!(s.is_complete());
        let updates = s.take_updates();
        assert_eq!(updates.len(), 3);
        assert_eq!(updates[0].0, 0); // ordered by collaborator
        assert_eq!(updates[1].1, 10);
    }

    #[test]
    fn round_state_rejects_stale_round() {
        let mut s = RoundState::new(5, [0]);
        let err = s.accept(4, 0, 1, upd()).unwrap_err();
        assert!(err.to_string().contains("stale"));
        assert!(s.accept(6, 0, 1, upd()).is_err());
    }

    #[test]
    fn round_state_rejects_duplicate() {
        let mut s = RoundState::new(0, [0, 1]);
        s.accept(0, 0, 1, upd()).unwrap();
        let err = s.accept(0, 0, 1, upd()).unwrap_err();
        assert!(err.to_string().contains("duplicate"));
    }

    #[test]
    fn round_state_rejects_unknown_collaborator() {
        let mut s = RoundState::new(0, [0, 1]);
        let err = s.accept(0, 9, 1, upd()).unwrap_err();
        assert!(err.to_string().contains("unknown"));
    }

    #[test]
    fn decoder_registry_single_registration() {
        let mut reg = DecoderRegistry::default();
        assert!(reg.is_empty());
        reg.register(0, vec![1.0]).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get(0).unwrap(), &[1.0]);
        assert!(reg.register(0, vec![2.0]).is_err());
        assert!(reg.get(1).is_err());
    }
}
