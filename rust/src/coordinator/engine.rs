//! Parallel round engine: fans per-collaborator work across scoped threads.
//!
//! The paper's headline result (§5: 500x–1720x compression) only matters
//! "in large scale federated learning", which means simulations need to
//! reach hundreds to thousands of collaborators. Collaborator work inside
//! a round — local training, AE encoding, the simulated upload — is
//! embarrassingly parallel: every collaborator owns its shard, its model
//! copy, its compressor and its RNG stream, and only shares the immutable
//! [`crate::runtime::Runtime`]. [`ParallelRoundEngine`] exploits exactly
//! that: it splits the participant list into contiguous chunks and runs
//! one `std::thread::scope` worker per chunk.
//!
//! ## Determinism
//!
//! Parallel execution is bitwise-identical to sequential execution:
//!
//! * each collaborator's computation depends only on its own state (seeded
//!   per-collaborator RNG, own parameters) — thread interleaving cannot
//!   touch it;
//! * [`ParallelRoundEngine::map`] returns results in input order, so the
//!   coordinator consumes train losses, updates and ledger records in
//!   collaborator-id order no matter which worker finished first;
//! * aggregation therefore sees the exact same operand order as the
//!   sequential driver, so even non-associative f32 reductions match.
//!
//! `rust/tests/parallel_round.rs` pins this property, and
//! `benches/bench_parallel_round.rs` measures the wall-clock speedup.

/// A scoped-thread fan-out pool with a fixed worker count.
///
/// Construct once per driver ([`crate::config::EngineConfig::parallelism`]
/// chooses the width: `1` = run inline on the caller's thread, `0` = use
/// [`std::thread::available_parallelism`]) and call [`ParallelRoundEngine::map`]
/// once per round phase.
#[derive(Debug, Clone)]
pub struct ParallelRoundEngine {
    workers: usize,
}

impl ParallelRoundEngine {
    /// Build an engine with `workers` threads; `0` selects the machine's
    /// available parallelism (falling back to 1 if it cannot be queried).
    pub fn new(workers: usize) -> ParallelRoundEngine {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(1)
        } else {
            workers
        };
        ParallelRoundEngine { workers }
    }

    /// The resolved worker count (never 0).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Split `items` into at most [`ParallelRoundEngine::workers`]
    /// contiguous, non-empty chunks, preserving input order across the
    /// concatenation. This is the fan-out unit of
    /// [`ParallelRoundEngine::map`], and the coordinator reuses it to
    /// chunk per-shard aggregation streams across workers (streaming
    /// server path): contiguity keeps result order deterministic and
    /// gives each worker a cache-friendly run of items.
    pub fn chunk<T>(&self, items: Vec<T>) -> Vec<Vec<T>> {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.workers.min(n);
        let chunk_len = (n + workers - 1) / workers;
        let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
        let mut it = items.into_iter();
        loop {
            let chunk: Vec<T> = it.by_ref().take(chunk_len).collect();
            if chunk.is_empty() {
                break;
            }
            chunks.push(chunk);
        }
        chunks
    }

    /// Apply `f` to every item, preserving input order in the returned
    /// vector regardless of worker scheduling.
    ///
    /// Items are split into at most `workers` contiguous chunks
    /// ([`ParallelRoundEngine::chunk`]), one scoped thread per chunk;
    /// with one worker (or one item) everything runs inline on the
    /// caller's thread with no spawn overhead. Worker panics propagate
    /// to the caller.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        if self.workers.min(items.len()) <= 1 {
            return items.into_iter().map(f).collect();
        }
        let chunks = self.chunk(items);
        let f = &f;
        let per_chunk: Vec<Vec<R>> = std::thread::scope(|s| {
            let handles: Vec<_> = chunks
                .into_iter()
                .map(|chunk| s.spawn(move || chunk.into_iter().map(f).collect::<Vec<R>>()))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    // Re-raise worker panics with their original payload.
                    h.join()
                        .unwrap_or_else(|payload| std::panic::resume_unwind(payload))
                })
                .collect()
        });
        per_chunk.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_workers_resolves_to_available_parallelism() {
        assert!(ParallelRoundEngine::new(0).workers() >= 1);
        assert_eq!(ParallelRoundEngine::new(3).workers(), 3);
    }

    #[test]
    fn map_preserves_order() {
        for workers in [1, 2, 3, 8, 64] {
            let engine = ParallelRoundEngine::new(workers);
            let items: Vec<usize> = (0..37).collect();
            let out = engine.map(items, |i| i * 2);
            assert_eq!(out, (0..37).map(|i| i * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn chunk_is_contiguous_order_preserving_and_bounded() {
        for workers in [1, 2, 3, 8, 64] {
            let engine = ParallelRoundEngine::new(workers);
            for n in [0usize, 1, 5, 37] {
                let chunks = engine.chunk((0..n).collect::<Vec<usize>>());
                assert!(chunks.len() <= workers.max(1), "n={n} workers={workers}");
                assert!(chunks.iter().all(|c| !c.is_empty()));
                let flat: Vec<usize> = chunks.into_iter().flatten().collect();
                assert_eq!(flat, (0..n).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let engine = ParallelRoundEngine::new(4);
        assert_eq!(engine.map(Vec::<usize>::new(), |i| i), Vec::<usize>::new());
        assert_eq!(engine.map(vec![9usize], |i| i + 1), vec![10]);
    }

    #[test]
    fn map_runs_on_multiple_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let engine = ParallelRoundEngine::new(4);
        let seen = Mutex::new(HashSet::new());
        engine.map((0..16).collect::<Vec<usize>>(), |_| {
            seen.lock().unwrap().insert(std::thread::current().id());
        });
        // 16 items over 4 workers must use more than one thread.
        assert!(seen.lock().unwrap().len() > 1);
    }

    #[test]
    fn map_with_mutable_borrows() {
        // The coordinator hands the engine `&mut Collaborator` items;
        // model that shape: disjoint mutable borrows fanned across workers.
        let engine = ParallelRoundEngine::new(3);
        let mut values = vec![0u64; 10];
        let tasks: Vec<(usize, &mut u64)> = values.iter_mut().enumerate().collect();
        let out = engine.map(tasks, |(i, v)| {
            *v = i as u64 + 1;
            *v
        });
        assert_eq!(out, (1..=10).collect::<Vec<u64>>());
        assert_eq!(values, (1..=10).collect::<Vec<u64>>());
    }
}
