//! Deadline-driven async round engine: straggler admission, late-update
//! buffering, staleness accounting.
//!
//! The paper's communication model (Fig 3) closes every round with a full
//! barrier: the aggregator waits for *all* selected collaborators'
//! AE-compressed updates before averaging. That is the right abstraction
//! for the paper's 2-collaborator experiments (§5.2), but at the
//! "large scale" its title targets, a barrier round is gated by the
//! slowest client: the survey in PAPERS.md (Shahid et al. 2021) names
//! client heterogeneity and partial participation as the dominant
//! communication cost next to update size, and Mitchell et al. (2022)
//! frame the same trade as rate-distortion — fidelity of what the server
//! hears vs. when it gets to act.
//!
//! [`AsyncRoundEngine`] replaces the barrier with a *wall-clock deadline
//! model* over the same metered protocol:
//!
//! 1. The round opens with the usual global-model broadcast; every
//!    selected collaborator trains and uploads exactly as in sync mode
//!    (same bytes, same [`crate::network::TrafficLedger`] metering).
//! 2. Each upload's simulated arrival time is the metered compressed
//!    frame bytes costed over the shared link
//!    ([`crate::network::Link::transfer_time`]) transformed by the
//!    seeded [`StragglerModel`] (persistent per-client slowdown, jitter,
//!    dropout).
//! 3. Arrivals at or before [`deadline`](crate::config::EngineConfig::deadline_ms)
//!    are **admitted** into the round's aggregation. Later arrivals are
//!    **buffered** — their bytes were spent, but the information lands
//!    `ceil(t/deadline) - 1` rounds later and is folded in
//!    staleness-discounted: the batch server paths scale weights through
//!    [`crate::aggregation::Aggregator::aggregate_stale`] /
//!    [`crate::aggregation::Aggregator::aggregate_shard_stale`], and the
//!    streaming accumulator path bakes the same per-update staleness
//!    tags into its [`crate::aggregation::StreamPlan`] weights, applying
//!    the identical `α/(s+1)` discount arithmetic — so the async
//!    engine composes with every `agg_path`/`shard_size`/`parallelism`
//!    setting unchanged. Dropped uploads never arrive and meter nothing.
//!
//! Everything is deterministic for a fixed experiment seed — admitted
//! set, buffer contents, ledger, global parameters — at any
//! `engine.parallelism` / `engine.shard_size` setting, because the
//! straggler model is a pure function of `(seed, round, collaborator)`
//! and the driver folds results in collaborator-id order
//! (`rust/tests/async_round.rs`). The degenerate configuration (zero
//! dropout, zero latency knobs, infinite deadline) admits everything at
//! the sync arrival times and reproduces the sequential sync engine
//! bitwise.

use crate::compression::CompressedUpdate;
use crate::config::{EngineConfig, EngineMode};
use crate::network::StragglerModel;

/// One late update parked in the server-side buffer until the round it
/// (simulated-)arrives in.
#[derive(Debug, Clone)]
pub struct BufferedUpdate {
    /// Sender.
    pub collaborator: usize,
    /// Sender's local sample count (the FedAvg weight, pre-discount).
    pub n_samples: u32,
    /// The compressed update as it came off the wire.
    pub update: CompressedUpdate,
    /// Round whose broadcast this update was trained against.
    pub origin_round: usize,
    /// First round whose aggregation may include it.
    pub apply_round: usize,
}

/// Per-round straggler/deadline accounting, carried on
/// [`crate::coordinator::RoundOutcome`]. In sync mode every upload is
/// admitted and only `admitted` / `sim_round_seconds` are populated.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StragglerStats {
    /// Fresh updates that arrived at or before the deadline.
    pub admitted: usize,
    /// Updates that arrived after the deadline and were buffered.
    pub late: usize,
    /// Uploads that never arrived (client dropout).
    pub dropped: usize,
    /// Buffered updates from earlier rounds folded into this round's
    /// aggregation.
    pub stale_applied: usize,
    /// Largest staleness (rounds) among the updates applied this round.
    pub max_staleness: usize,
    /// Simulated wall-clock duration of the round: the deadline when
    /// anything was late or dropped, otherwise the latest arrival.
    pub sim_round_seconds: f64,
}

/// The deadline-driven round engine state: straggler model, deadline,
/// the late-update buffer, and cumulative accounting.
///
/// Owned by [`crate::coordinator::FlDriver`] when
/// [`crate::config::EngineConfig::mode`] is
/// [`EngineMode::Async`]; the driver consults it at
/// three points per round — upload fate (via the shared
/// [`StragglerModel`] copy), admission vs. buffering at fold time, and
/// draining due buffered updates into the aggregation inputs.
#[derive(Debug)]
pub struct AsyncRoundEngine {
    deadline_s: f64,
    staleness_decay: f64,
    model: StragglerModel,
    pending: Vec<BufferedUpdate>,
    totals: StragglerStats,
}

impl AsyncRoundEngine {
    /// Build the engine for an async-mode config (`None` for sync mode).
    /// `seed` is the experiment master seed; the straggler model draws
    /// from a stream derived from it.
    pub fn from_config(cfg: &EngineConfig, seed: u64) -> Option<AsyncRoundEngine> {
        if cfg.mode != EngineMode::Async {
            return None;
        }
        let deadline_s = if cfg.deadline_ms > 0.0 {
            cfg.deadline_ms * 1e-3
        } else {
            f64::INFINITY
        };
        Some(AsyncRoundEngine {
            deadline_s,
            staleness_decay: cfg.staleness_decay,
            model: StragglerModel::from_config(cfg, seed ^ 0xA57C_5EED_0000_0007),
            pending: Vec::new(),
            totals: StragglerStats::default(),
        })
    }

    /// The shared straggler model (`Copy`, so round workers evaluate
    /// upload fates on their own threads).
    pub fn model(&self) -> StragglerModel {
        self.model
    }

    /// The round deadline in simulated seconds (`f64::INFINITY` when the
    /// config's `deadline_ms` is 0).
    pub fn deadline_seconds(&self) -> f64 {
        self.deadline_s
    }

    /// The staleness decay coefficient handed to
    /// [`crate::aggregation::staleness_discount`].
    pub fn staleness_decay(&self) -> f64 {
        self.staleness_decay
    }

    /// Park a late upload from `round` until the round its arrival time
    /// falls in. With deadline `D`, an arrival at `t > D` lands
    /// `ceil(t / D) - 1` rounds later.
    ///
    /// In-flight pacing treats every round as lasting exactly `D`. That
    /// is an approximation: a round in which everything arrived on time
    /// closes early (at its last arrival, see
    /// [`StragglerStats::sim_round_seconds`]), so the cumulative
    /// simulated clock can run ahead of `apply_round x D`. The
    /// round-granular model keeps staleness integral and admission
    /// deterministic; cumulative-clock pacing is a noted extension.
    pub fn buffer_late(
        &mut self,
        round: usize,
        collaborator: usize,
        n_samples: u32,
        update: CompressedUpdate,
        arrival_s: f64,
    ) {
        debug_assert!(arrival_s > self.deadline_s);
        let rounds_late = if self.deadline_s.is_finite() && self.deadline_s > 0.0 {
            (((arrival_s / self.deadline_s).ceil() as usize).saturating_sub(1)).max(1)
        } else {
            // Unreachable in practice (an infinite deadline admits every
            // arrival); kept total for safety.
            1
        };
        self.pending.push(BufferedUpdate {
            collaborator,
            n_samples,
            update,
            origin_round: round,
            apply_round: round + rounds_late,
        });
    }

    /// Drain every buffered update due at `round` (in buffering order,
    /// which is deterministic: rounds are folded in collaborator-id
    /// order). The caller tags each with staleness
    /// `round - origin_round`.
    pub fn drain_due(&mut self, round: usize) -> Vec<BufferedUpdate> {
        let (due, rest): (Vec<_>, Vec<_>) = self
            .pending
            .drain(..)
            .partition(|b| b.apply_round <= round);
        self.pending = rest;
        due
    }

    /// Updates still in flight (buffered, not yet due).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Collaborator ids with at least one buffered update still in
    /// flight. The driver pins these in its resident-client pool: a
    /// buffered update needs its sender's server-side decompressor (and,
    /// for fresh-MSE bookkeeping, collaborator state) alive through its
    /// apply round, so eviction must skip them.
    pub fn pending_collaborators(&self) -> impl Iterator<Item = usize> + '_ {
        self.pending.iter().map(|b| b.collaborator)
    }

    /// Fold one round's stats into the running totals
    /// (`sim_round_seconds` accumulates into total simulated experiment
    /// time).
    pub fn record_round(&mut self, stats: &StragglerStats) {
        self.totals.admitted += stats.admitted;
        self.totals.late += stats.late;
        self.totals.dropped += stats.dropped;
        self.totals.stale_applied += stats.stale_applied;
        self.totals.max_staleness = self.totals.max_staleness.max(stats.max_staleness);
        self.totals.sim_round_seconds += stats.sim_round_seconds;
    }

    /// Cumulative accounting across all rounds run so far
    /// (`sim_round_seconds` is the total simulated experiment duration).
    pub fn totals(&self) -> StragglerStats {
        self.totals
    }

    /// The buffered updates still in flight, in buffering order — the
    /// async half of a checkpoint snapshot, paired with
    /// [`AsyncRoundEngine::totals`] (see
    /// [`crate::coordinator::checkpoint`]).
    pub fn pending(&self) -> &[BufferedUpdate] {
        &self.pending
    }

    /// Restore the late-update buffer and cumulative totals from a
    /// checkpoint snapshot. Everything else the engine holds — deadline,
    /// decay, straggler model — is a pure function of config + seed and
    /// is rebuilt by [`AsyncRoundEngine::from_config`], so this
    /// completes the engine's cross-round state.
    pub fn restore(&mut self, pending: Vec<BufferedUpdate>, totals: StragglerStats) {
        self.pending = pending;
        self.totals = totals;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_async(deadline_ms: f64) -> EngineConfig {
        EngineConfig {
            mode: EngineMode::Async,
            deadline_ms,
            ..EngineConfig::default()
        }
    }

    fn upd() -> CompressedUpdate {
        CompressedUpdate::Raw { values: vec![1.0] }
    }

    #[test]
    fn sync_config_builds_no_engine() {
        assert!(AsyncRoundEngine::from_config(&EngineConfig::default(), 1).is_none());
        assert!(AsyncRoundEngine::from_config(&cfg_async(0.0), 1).is_some());
    }

    #[test]
    fn zero_deadline_means_infinite() {
        let e = AsyncRoundEngine::from_config(&cfg_async(0.0), 1).unwrap();
        assert!(e.deadline_seconds().is_infinite());
        let e = AsyncRoundEngine::from_config(&cfg_async(250.0), 1).unwrap();
        assert!((e.deadline_seconds() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn late_updates_land_per_deadline_pacing() {
        // Deadline 100 ms: arrival at 150 ms -> next round; at 350 ms ->
        // three rounds later.
        let mut e = AsyncRoundEngine::from_config(&cfg_async(100.0), 1).unwrap();
        e.buffer_late(4, 0, 10, upd(), 0.15);
        e.buffer_late(4, 1, 10, upd(), 0.35);
        assert_eq!(e.pending_len(), 2);
        // Round 5: only the 150 ms arrival is due.
        let due = e.drain_due(5);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].collaborator, 0);
        assert_eq!(due[0].origin_round, 4);
        assert_eq!(due[0].apply_round, 5);
        assert_eq!(e.pending_len(), 1);
        // Round 6: nothing due yet; round 7 drains the 350 ms arrival.
        assert!(e.drain_due(6).is_empty());
        let due = e.drain_due(7);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].apply_round, 7);
        assert_eq!(e.pending_len(), 0);
    }

    #[test]
    fn drain_preserves_buffering_order() {
        let mut e = AsyncRoundEngine::from_config(&cfg_async(100.0), 1).unwrap();
        for cid in 0..4 {
            e.buffer_late(0, cid, 1, upd(), 0.11);
        }
        let due = e.drain_due(1);
        let order: Vec<usize> = due.iter().map(|b| b.collaborator).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn totals_accumulate() {
        let mut e = AsyncRoundEngine::from_config(&cfg_async(100.0), 1).unwrap();
        e.record_round(&StragglerStats {
            admitted: 3,
            late: 1,
            dropped: 1,
            stale_applied: 0,
            max_staleness: 0,
            sim_round_seconds: 0.1,
        });
        e.record_round(&StragglerStats {
            admitted: 4,
            late: 0,
            dropped: 0,
            stale_applied: 1,
            max_staleness: 2,
            sim_round_seconds: 0.05,
        });
        let t = e.totals();
        assert_eq!(t.admitted, 7);
        assert_eq!(t.late, 1);
        assert_eq!(t.dropped, 1);
        assert_eq!(t.stale_applied, 1);
        assert_eq!(t.max_staleness, 2);
        assert!((t.sim_round_seconds - 0.15).abs() < 1e-12);
    }
}
