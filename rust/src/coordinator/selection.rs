//! Seeded per-round client selection for large registered populations.
//!
//! The paper's experiments run every configured collaborator every round,
//! which is fine at 2–1024 clients but not at the "millions of users" its
//! title gestures at: the standard lever alongside update compression is
//! *client subsampling* — pick K of the N registered clients per round —
//! and the communication-efficiency surveys in PAPERS.md treat the two as
//! composable reductions. This module supplies that layer.
//!
//! Design constraints, in order:
//!
//! 1. **Pure function of `(seed, round, policy)`.** Like
//!    [`crate::network::StragglerModel`], a selector owns no advancing
//!    RNG: every round derives a fresh stream from
//!    `seed ^ round * PHI64`. Replaying round `r` — on another thread
//!    count, another shard size, another aggregation path, or after a
//!    crash — yields the identical participant set.
//! 2. **O(K) work and memory for the uniform policy.** Sampling K of
//!    1,000,000 must not allocate a million-entry permutation.
//!    [`sample_indices_sparse`] runs the same partial Fisher–Yates walk
//!    as [`Rng::sample_indices`] but keeps only the O(K) displaced
//!    entries in a hash map, so it is bitwise-identical to the dense
//!    version on the same RNG stream while never touching O(N) memory.
//! 3. **K = N degenerates to everyone.** Every selector returns
//!    `0..n` without drawing a single random number when `k >= n`, so a
//!    full-participation config is bitwise-identical to a driver with no
//!    selection layer at all.
//!
//! Three policies are provided: [`UniformSelector`] (each client equally
//! likely), [`WeightedSelector`] (inclusion probability proportional to a
//! per-client weight, e.g. local sample count, via the
//! Efraimidis–Spirakis exponential-keys method), and
//! [`StratifiedSelector`] (clients partitioned into strata by
//! `id % strata`; the per-round quota is split across strata by largest
//! remainder and sampled uniformly within each). The driver consumes
//! them behind the [`ClientSelector`] trait and reports per-round
//! [`SelectionStats`] on [`crate::coordinator::RoundOutcome`].

use std::collections::HashMap;

use crate::util::rng::Rng;

/// Golden-ratio odd constant used across the crate to decorrelate
/// per-round streams (`Rng::new(seed ^ round * PHI64)`).
const PHI64: u64 = 0x9E37_79B9_7F4A_7C15;

/// Second odd constant mixing the stratum index into the per-round
/// stream so strata draw from unrelated sequences.
const STRATUM_MIX: u64 = 0xD1B5_4A32_D192_ED03;

/// Per-round selection and resident-pool accounting, carried on
/// [`crate::coordinator::RoundOutcome`]. Like the `agg` stats, this is
/// *accounting*, not *results*: it is excluded from `RoundOutcome`
/// equality so bitwise-parity suites compare outcomes across resident
/// bounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SelectionStats {
    /// Clients sampled for this round (K, or K + slack in async mode).
    pub sampled: usize,
    /// Sampled clients that had no resident state and were activated
    /// (shard synthesized, compressor built, decoder registered).
    pub newly_activated: usize,
    /// Resident clients evicted after this round to satisfy
    /// `selection.max_resident`.
    pub evicted: usize,
    /// Clients resident after this round's eviction pass.
    pub resident: usize,
    /// On-time arrivals beyond the K admission target that were
    /// discarded (async over-provisioned sampling only).
    pub discarded: usize,
}

/// A per-round client-selection policy. `select` must return a sorted,
/// duplicate-free subset of `0..n`, must be a pure function of
/// `(self, round, n, k)`, and must return `0..n` (drawing nothing) when
/// `k >= n`.
pub trait ClientSelector: Send + Sync {
    /// Short policy name for logs and summaries.
    fn name(&self) -> &'static str;

    /// Choose `k` distinct client ids out of `0..n` for `round`.
    fn select(&self, round: usize, n: usize, k: usize) -> Vec<usize>;
}

/// Sample `k` distinct indices from `[0, n)` using O(k) time and memory.
///
/// This replays [`Rng::sample_indices`]'s partial Fisher–Yates walk —
/// same `below(n - i)` draws in the same order — but tracks only the
/// displaced entries in a hash map instead of materializing the identity
/// permutation, so the result is **bitwise-identical** to the dense
/// version on an identically-seeded RNG (pinned by
/// `tests/prop_invariants.rs`) while the cost is independent of `n`.
/// Positions `<= i` are never read again (the draw is `j >= i`), so only
/// the forward displacement needs recording.
pub fn sample_indices_sparse(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n, "sample_indices_sparse: k > n");
    let mut swapped: HashMap<usize, usize> = HashMap::with_capacity(k * 2);
    let mut out = Vec::with_capacity(k);
    for i in 0..k {
        let j = i + rng.below(n - i);
        let vi = *swapped.get(&i).unwrap_or(&i);
        let vj = *swapped.get(&j).unwrap_or(&j);
        out.push(vj);
        swapped.insert(j, vi);
    }
    out
}

/// Derive the per-round selection RNG: a pure function of
/// `(seed, round)`, so any round is replayable in isolation.
fn round_rng(seed: u64, round: usize) -> Rng {
    Rng::new(seed ^ (round as u64).wrapping_mul(PHI64))
}

/// Uniform K-of-N selection: every client equally likely each round,
/// sampled without replacement in O(K).
#[derive(Debug, Clone)]
pub struct UniformSelector {
    seed: u64,
}

impl UniformSelector {
    /// Build a uniform selector over the given selection seed.
    pub fn new(seed: u64) -> Self {
        UniformSelector { seed }
    }
}

impl ClientSelector for UniformSelector {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn select(&self, round: usize, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        let mut rng = round_rng(self.seed, round);
        let mut sel = sample_indices_sparse(&mut rng, n, k);
        sel.sort_unstable();
        sel
    }
}

/// Weighted K-of-N selection via Efraimidis–Spirakis exponential keys:
/// each client draws `u^(1/w)` and the k largest keys win, giving
/// inclusion probabilities proportional to the weights (e.g. local
/// sample counts) without replacement.
///
/// Unlike [`UniformSelector`] this is O(N log N) per round — one uniform
/// draw and a sort key per registered client — but it holds no
/// per-client *state*, so resident memory stays O(active). For uniform
/// weights prefer [`UniformSelector`].
#[derive(Debug, Clone)]
pub struct WeightedSelector {
    seed: u64,
    weights: Vec<f64>,
}

impl WeightedSelector {
    /// Build a weighted selector. Every weight must be strictly
    /// positive; `weights.len()` fixes the population the selector can
    /// serve.
    pub fn new(seed: u64, weights: Vec<f64>) -> Self {
        assert!(
            weights.iter().all(|&w| w > 0.0 && w.is_finite()),
            "WeightedSelector: weights must be finite and > 0"
        );
        WeightedSelector { seed, weights }
    }
}

impl ClientSelector for WeightedSelector {
    fn name(&self) -> &'static str {
        "weighted"
    }

    fn select(&self, round: usize, n: usize, k: usize) -> Vec<usize> {
        assert_eq!(self.weights.len(), n, "WeightedSelector: population mismatch");
        if k >= n {
            return (0..n).collect();
        }
        let mut rng = round_rng(self.seed, round);
        // Key u^(1/w) per client, largest k win. Ties (vanishingly rare)
        // break toward the lower id for determinism.
        let mut keyed: Vec<(f64, usize)> = (0..n)
            .map(|c| (rng.uniform().powf(1.0 / self.weights[c]), c))
            .collect();
        keyed.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        let mut sel: Vec<usize> = keyed[..k].iter().map(|&(_, c)| c).collect();
        sel.sort_unstable();
        sel
    }
}

/// Stratified K-of-N selection: clients are partitioned into `strata`
/// groups by `id % strata` (the driver assigns data shards round-robin,
/// so `id % strata` groups clients by shard family), the round quota is
/// apportioned across strata by largest remainder, and each stratum
/// samples its quota uniformly (O(quota) per stratum) from an
/// independent per-`(round, stratum)` stream.
#[derive(Debug, Clone)]
pub struct StratifiedSelector {
    seed: u64,
    strata: usize,
}

impl StratifiedSelector {
    /// Build a stratified selector with `strata >= 1` groups.
    pub fn new(seed: u64, strata: usize) -> Self {
        assert!(strata >= 1, "StratifiedSelector: strata must be >= 1");
        StratifiedSelector { seed, strata }
    }

    /// Number of clients in stratum `s` for population `n`
    /// (members are `s, s + strata, s + 2*strata, ...`).
    fn stratum_size(&self, n: usize, s: usize) -> usize {
        n.saturating_sub(s).div_ceil(self.strata)
    }

    /// Largest-remainder apportionment of `k` slots across the strata,
    /// capped at each stratum's size (total capacity is `n >= k`, so the
    /// remainder always places).
    fn apportion(&self, n: usize, k: usize) -> Vec<usize> {
        let sizes: Vec<usize> = (0..self.strata).map(|s| self.stratum_size(n, s)).collect();
        let mut alloc: Vec<usize> = sizes.iter().map(|&sz| k * sz / n).collect();
        let mut remaining = k - alloc.iter().sum::<usize>();
        // Order strata by descending fractional remainder (k*sz mod n),
        // ties toward the lower stratum index.
        let mut order: Vec<usize> = (0..self.strata).collect();
        order.sort_unstable_by_key(|&s| (std::cmp::Reverse(k * sizes[s] % n), s));
        while remaining > 0 {
            for &s in &order {
                if remaining == 0 {
                    break;
                }
                if alloc[s] < sizes[s] {
                    alloc[s] += 1;
                    remaining -= 1;
                }
            }
        }
        alloc
    }
}

impl ClientSelector for StratifiedSelector {
    fn name(&self) -> &'static str {
        "stratified"
    }

    fn select(&self, round: usize, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        let alloc = self.apportion(n, k);
        let mut sel = Vec::with_capacity(k);
        for (s, &quota) in alloc.iter().enumerate() {
            if quota == 0 {
                continue;
            }
            let size = self.stratum_size(n, s);
            let mut rng = Rng::new(
                self.seed
                    ^ (round as u64).wrapping_mul(PHI64)
                    ^ (s as u64).wrapping_mul(STRATUM_MIX),
            );
            for j in sample_indices_sparse(&mut rng, size, quota) {
                sel.push(s + j * self.strata);
            }
        }
        sel.sort_unstable();
        sel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_sampling_matches_dense_bitwise() {
        for (n, k) in [(1, 1), (10, 3), (100, 100), (257, 64), (1000, 1)] {
            for seed in [0u64, 7, 0xDEAD_BEEF] {
                let dense = Rng::new(seed).sample_indices(n, k);
                let sparse = sample_indices_sparse(&mut Rng::new(seed), n, k);
                assert_eq!(dense, sparse, "n={n} k={k} seed={seed}");
            }
        }
    }

    fn assert_valid(sel: &[usize], n: usize, k: usize) {
        assert_eq!(sel.len(), k);
        assert!(sel.windows(2).all(|w| w[0] < w[1]), "not sorted/distinct");
        assert!(sel.iter().all(|&c| c < n));
    }

    #[test]
    fn uniform_is_sorted_distinct_and_deterministic() {
        let s = UniformSelector::new(42);
        for round in 0..8 {
            let a = s.select(round, 1000, 32);
            assert_valid(&a, 1000, 32);
            assert_eq!(a, s.select(round, 1000, 32), "round replay diverged");
        }
        assert_ne!(s.select(0, 1000, 32), s.select(1, 1000, 32));
    }

    #[test]
    fn k_of_n_degenerates_to_everyone() {
        let n = 17;
        let all: Vec<usize> = (0..n).collect();
        assert_eq!(UniformSelector::new(3).select(5, n, n), all);
        assert_eq!(UniformSelector::new(3).select(5, n, n + 4), all);
        assert_eq!(
            WeightedSelector::new(3, vec![1.0; n]).select(5, n, n),
            all
        );
        assert_eq!(StratifiedSelector::new(3, 4).select(5, n, n), all);
    }

    #[test]
    fn uniform_population_cost_is_independent_of_n() {
        // Selecting 256 of a million allocates O(k): this would OOM or
        // time out long before the suite does if it were O(n).
        let s = UniformSelector::new(9);
        let sel = s.select(0, 1_000_000, 256);
        assert_valid(&sel, 1_000_000, 256);
    }

    #[test]
    fn uniform_hit_counts_are_roughly_flat() {
        let n = 40;
        let k = 8;
        let rounds = 4000;
        let s = UniformSelector::new(77);
        let mut hits = vec![0usize; n];
        for r in 0..rounds {
            for c in s.select(r, n, k) {
                hits[c] += 1;
            }
        }
        let expect = (rounds * k / n) as f64; // 800 per client
        for (c, &h) in hits.iter().enumerate() {
            assert!(
                (h as f64 - expect).abs() < 0.15 * expect,
                "client {c}: {h} hits vs ~{expect}"
            );
        }
    }

    #[test]
    fn weighted_prefers_heavier_clients() {
        let n = 20;
        // First half weight 1, second half weight 4.
        let weights: Vec<f64> = (0..n).map(|c| if c < n / 2 { 1.0 } else { 4.0 }).collect();
        let s = WeightedSelector::new(5, weights);
        let mut light = 0usize;
        let mut heavy = 0usize;
        for r in 0..2000 {
            for c in s.select(r, n, 5) {
                if c < n / 2 {
                    light += 1;
                } else {
                    heavy += 1;
                }
            }
        }
        assert!(
            heavy as f64 > 2.0 * light as f64,
            "heavy={heavy} light={light}"
        );
    }

    #[test]
    fn stratified_apportions_exactly_and_stays_in_stratum() {
        let n = 103; // strata of sizes 26, 26, 26, 25 at strata=4
        let strata = 4;
        let k = 10;
        let s = StratifiedSelector::new(11, strata);
        for round in 0..16 {
            let sel = s.select(round, n, k);
            assert_valid(&sel, n, k);
            let mut per = vec![0usize; strata];
            for &c in &sel {
                per[c % strata] += 1;
            }
            // Largest remainder on sizes (26,26,26,25), k=10: quotas
            // floor to (2,2,2,2) with remainders giving (3,3,2,2).
            assert_eq!(per, vec![3, 3, 2, 2], "round {round}");
        }
    }

    #[test]
    fn apportionment_sums_to_k_and_respects_capacity() {
        for (n, strata, k) in [(10, 3, 10), (11, 4, 7), (1000, 7, 256), (5, 5, 3)] {
            let s = StratifiedSelector::new(1, strata);
            let alloc = s.apportion(n, k);
            assert_eq!(alloc.iter().sum::<usize>(), k, "n={n} strata={strata}");
            for (i, &a) in alloc.iter().enumerate() {
                assert!(a <= s.stratum_size(n, i));
            }
        }
    }
}
