//! `fedae` — CLI for the AE-compressed federated learning runtime.
//!
//! Subcommands:
//! * `train`    — run a federated experiment from a JSON config (or flags).
//! * `prepass`  — run only the pre-pass round and report AE training curves.
//! * `savings`  — evaluate the paper's Eq. 4–6 savings model (Figs 10/11).
//! * `inspect`  — print manifest / artifact info.
//! * `serve` / `worker` — the same full pipeline as a multi-process TCP
//!   federation (message-driven coordinator, bitwise parity with `train`).
//!
//! Examples:
//! ```text
//! fedae train --model mnist --compression ae --rounds 10
//! fedae savings --rounds 100 --max-collabs 2000
//! fedae serve --port 7070 --compression ae --collabs 2 --rounds 3 &
//! fedae worker --connect 127.0.0.1:7070 --id 0 --compression ae --collabs 2 --rounds 3 &
//! fedae worker --connect 127.0.0.1:7070 --id 1 --compression ae --collabs 2 --rounds 3
//! ```

use fedae::backend::Kernel;
use fedae::config::{AggPath, CompressionConfig, EngineMode, ExperimentConfig, SelectionPolicy};
use fedae::coordinator::FlDriver;
use fedae::error::FedAeError;
use fedae::metrics::{ascii_plot, print_table};
use fedae::runtime::{AePipeline, Runtime};
use fedae::savings::{SavingsModel, PAPER_CIFAR};
use fedae::util::cli::Args;

/// Binary-level result: any error class, printed with `Display` on exit.
type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("train") => cmd_train(&args),
        Some("prepass") => cmd_prepass(&args),
        Some("savings") => cmd_savings(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("serve") => fedae_serve(&args),
        Some("worker") => fedae_worker(&args),
        _ => {
            eprintln!(
                "usage: fedae <train|prepass|savings|inspect|serve|worker> [--flags]\n\
                 \n\
                 train    --config <file.json> | [--model mnist|cifar] [--compression ae|identity|topk|quantize|subsample|sketch]\n\
                 \u{20}        [--rounds N] [--collabs N] [--local-epochs N] [--seed N] [--out metrics.json]\n\
                 \u{20}        [--parallelism N (0 = all cores)] [--shard-size N (0 = unsharded aggregation)]\n\
                 \u{20}        [--agg-path auto|batch|stream (server aggregation execution path)]\n\
                 \u{20}        [--kernel naive|tiled|simd (native compute kernels)]\n\
                 \u{20}        [--step-parallelism N (threads per GEMM; bitwise-neutral, 0/1 = inline)]\n\
                 \u{20}        [--mode sync|async] [--deadline-ms N (0 = infinite)] [--dropout-rate X]\n\
                 \u{20}        [--staleness-decay A] [--straggler-log-std S] [--jitter-ms N]\n\
                 \u{20}        [--selection uniform|weighted|stratified] [--select-fraction X] [--select-count K]\n\
                 \u{20}        [--select-slack S (async over-provisioning)] [--max-resident N (0 = unbounded)] [--strata N]\n\
                 \u{20}        [--checkpoint-dir DIR] [--checkpoint-every N] [--keep-last K (0 = keep all)]\n\
                 \u{20}        [--resume PATH (snapshot file or checkpoint dir; continues the run bitwise)]\n\
                 prepass  [--model mnist|cifar] [--ae mnist|cifar|mnist_deep] [--epochs N] [--ae-epochs N] [--kernel naive|tiled|simd]\n\
                 savings  [--rounds N] [--max-collabs N] [--mnist]\n\
                 inspect  [--artifacts DIR]\n\
                 serve    --port P [any train flags] [--min-participants N (0 = all collabs)]\n\
                 \u{20}        [--heartbeat-ms N] [--round-timeout-ms N] [--max-frame-bytes N]\n\
                 \u{20}        [--quorum N (0 = off; commit a degraded round with >= N survivors)]\n\
                 \u{20}        [--rejoin-grace-ms N (grace before a dead worker is evicted)]\n\
                 worker   --connect HOST:PORT --id K [same config flags as the coordinator]\n\
                 \u{20}        [--retry-max N (send/recv attempts, >= 1)] [--retry-base-ms N (backoff base)]"
            );
            std::process::exit(2);
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

/// The `--kernel` flag (native compute-kernel selection; default tiled).
fn kernel_from_args(args: &Args) -> Result<Kernel> {
    match args.get("kernel") {
        Some(k) => Ok(Kernel::parse(k)?),
        None => Ok(Kernel::default()),
    }
}

/// Build an ExperimentConfig from either --config or individual flags.
fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::load(path)
            .map_err(|e| FedAeError::Config(format!("loading config {path}: {e}")))?
    } else {
        ExperimentConfig::default()
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
        // Keep the AE paired with the model unless overridden.
        if matches!(cfg.compression, CompressionConfig::Ae { .. }) {
            cfg.compression = CompressionConfig::Ae { ae: m.to_string() };
        }
    }
    if let Some(c) = args.get("compression") {
        cfg.compression = match c {
            "ae" => CompressionConfig::Ae {
                ae: args.get_or("ae", &cfg.model).to_string(),
            },
            "identity" => CompressionConfig::Identity,
            "topk" => CompressionConfig::TopK {
                fraction: args.get_f64("fraction", 0.01)?,
            },
            "quantize" => CompressionConfig::Quantize {
                bits: args.get_usize("bits", 8)? as u8,
                stochastic: args.flag("stochastic"),
            },
            "subsample" => CompressionConfig::Subsample {
                fraction: args.get_f64("fraction", 0.01)?,
            },
            "sketch" => CompressionConfig::Sketch {
                rows: args.get_usize("rows", 5)?,
                cols: args.get_usize("cols", 256)?,
                topk: args.get_usize("topk", 256)?,
            },
            other => return Err(format!("unknown compression `{other}`").into()),
        };
    }
    cfg.fl.rounds = args.get_usize("rounds", cfg.fl.rounds)?;
    cfg.fl.collaborators = args.get_usize("collabs", cfg.fl.collaborators)?;
    cfg.fl.local_epochs = args.get_usize("local-epochs", cfg.fl.local_epochs)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.prepass.epochs = args.get_usize("prepass-epochs", cfg.prepass.epochs)?;
    cfg.prepass.ae_epochs = args.get_usize("ae-epochs", cfg.prepass.ae_epochs)?;
    cfg.data.per_collab = args.get_usize("per-collab", cfg.data.per_collab)?;
    cfg.data.test_size = args.get_usize("test-size", cfg.data.test_size)?;
    cfg.engine.parallelism = args.get_usize("parallelism", cfg.engine.parallelism)?;
    cfg.engine.shard_size = args.get_usize("shard-size", cfg.engine.shard_size)?;
    if let Some(p) = args.get("agg-path") {
        cfg.engine.agg_path = AggPath::parse(p)?;
    }
    if let Some(m) = args.get("mode") {
        cfg.engine.mode = EngineMode::parse(m)?;
    }
    cfg.engine.deadline_ms = args.get_f64("deadline-ms", cfg.engine.deadline_ms)?;
    cfg.engine.staleness_decay = args.get_f64("staleness-decay", cfg.engine.staleness_decay)?;
    cfg.engine.dropout_rate = args.get_f64("dropout-rate", cfg.engine.dropout_rate)?;
    cfg.engine.straggler_log_std =
        args.get_f64("straggler-log-std", cfg.engine.straggler_log_std)?;
    cfg.engine.jitter_ms = args.get_f64("jitter-ms", cfg.engine.jitter_ms)?;
    if let Some(k) = args.get("kernel") {
        cfg.backend.kernel = Kernel::parse(k)?;
    }
    cfg.engine.step_parallelism =
        args.get_usize("step-parallelism", cfg.engine.step_parallelism)?;
    if let Some(p) = args.get("selection") {
        cfg.selection.policy = SelectionPolicy::parse(p)?;
    }
    cfg.selection.fraction = args.get_f64("select-fraction", cfg.selection.fraction)?;
    cfg.selection.count = args.get_usize("select-count", cfg.selection.count)?;
    cfg.selection.slack = args.get_usize("select-slack", cfg.selection.slack)?;
    cfg.selection.max_resident = args.get_usize("max-resident", cfg.selection.max_resident)?;
    cfg.selection.strata = args.get_usize("strata", cfg.selection.strata)?;
    cfg.protocol.min_participants =
        args.get_usize("min-participants", cfg.protocol.min_participants)?;
    cfg.protocol.heartbeat_ms = args.get_u64("heartbeat-ms", cfg.protocol.heartbeat_ms)?;
    cfg.protocol.round_timeout_ms =
        args.get_u64("round-timeout-ms", cfg.protocol.round_timeout_ms)?;
    cfg.protocol.max_frame_bytes =
        args.get_usize("max-frame-bytes", cfg.protocol.max_frame_bytes)?;
    cfg.protocol.quorum = args.get_usize("quorum", cfg.protocol.quorum)?;
    cfg.protocol.retry_max = args.get_usize("retry-max", cfg.protocol.retry_max as usize)? as u32;
    cfg.protocol.retry_base_ms = args.get_u64("retry-base-ms", cfg.protocol.retry_base_ms)?;
    cfg.protocol.rejoin_grace_ms = args.get_u64("rejoin-grace-ms", cfg.protocol.rejoin_grace_ms)?;
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.checkpoint.dir = dir.to_string();
    }
    cfg.checkpoint.every_rounds =
        args.get_usize("checkpoint-every", cfg.checkpoint.every_rounds)?;
    cfg.checkpoint.keep_last = args.get_usize("keep-last", cfg.checkpoint.keep_last)?;
    // Resuming implies checkpointing into the same directory when
    // --resume points at a directory and no explicit dir was given, so
    // the continued run keeps appending to the same event log.
    if !cfg.checkpoint.enabled() {
        if let Some(path) = args.get("resume") {
            if std::path::Path::new(path).is_dir() {
                cfg.checkpoint.dir = path.to_string();
            }
        }
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let rt = Runtime::builder()
        .artifacts_dir(artifacts_dir(args))
        .kernel(cfg.backend.kernel)
        .step_parallelism(cfg.engine.step_parallelism)
        .build()?;
    println!(
        "experiment `{}`: model={} compression={} rounds={} collabs={} parallelism={} shard_size={} agg_path={} mode={} kernel={}",
        cfg.name,
        cfg.model,
        cfg.compression.kind_name(),
        cfg.fl.rounds,
        cfg.fl.collaborators,
        cfg.engine.parallelism,
        cfg.engine.shard_size,
        cfg.engine.agg_path.name(),
        cfg.engine.mode.name(),
        cfg.backend.kernel.name()
    );
    let is_async = cfg.engine.mode == EngineMode::Async;
    let pipeline;
    let pipe_ref = match &cfg.compression {
        CompressionConfig::Ae { ae } => {
            pipeline = AePipeline::new(&rt, ae)?;
            println!(
                "pre-pass: training {}-dim AE (latent {}, ratio {:.0}x) per collaborator ...",
                pipeline.input_dim,
                pipeline.latent,
                pipeline.input_dim as f64 / pipeline.latent as f64
            );
            Some(&pipeline)
        }
        _ => None,
    };
    let mut builder = FlDriver::builder(&rt, cfg);
    if let Some(p) = pipe_ref {
        builder = builder.pipeline(p);
    }
    if let Some(path) = args.get("resume") {
        builder = builder.resume_from(path);
    }
    let mut driver = builder.build()?;
    if driver.round() > 0 {
        println!(
            "resumed at round {} ({} resident clients restored)",
            driver.round(),
            driver.resident_clients()
        );
    }
    let n_registered = driver.config().fl.collaborators;
    for r in driver.round()..driver.config().fl.rounds {
        let out = driver.run_round()?;
        let s = out.stragglers;
        let sel = out.selection;
        let sel_suffix = if sel.sampled < n_registered {
            format!(
                " sampled={} activated={} resident={}",
                sel.sampled, sel.newly_activated, sel.resident
            )
        } else {
            String::new()
        };
        let async_suffix = if is_async {
            format!(
                " admitted={} late={} dropped={} stale={} sim_s={:.3}",
                s.admitted, s.late, s.dropped, s.stale_applied, s.sim_round_seconds
            )
        } else {
            String::new()
        };
        println!(
            "round {r:>3}: eval_loss={:.4} eval_acc={:.4} up={}B down={}B recon_mse={:.2e} \
             agg_decodes={} agg_peak_floats={} agg_ms={:.1}{sel_suffix}{async_suffix}",
            out.eval_loss,
            out.eval_acc,
            out.bytes_up,
            out.bytes_down,
            out.mean_recon_mse,
            out.agg.full_decodes,
            out.agg.peak_floats,
            out.agg.ms
        );
    }
    let acc = driver.log.final_accuracy().unwrap_or(0.0);
    let ledger = driver.network.ledger();
    println!(
        "done: final_acc={acc:.4} total_bytes={} update_bytes_up={}",
        ledger.total_bytes(),
        ledger.update_bytes_up()
    );
    if let Some(t) = driver.async_totals() {
        println!(
            "async: admitted={} late={} dropped={} stale_applied={} pending={} sim_total_s={:.3}",
            t.admitted,
            t.late,
            t.dropped,
            t.stale_applied,
            driver.async_pending(),
            t.sim_round_seconds
        );
    }
    if let Some(out) = args.get("out") {
        driver.log.write_json(out)?;
        println!("metrics written to {out}");
    }
    Ok(())
}

fn cmd_prepass(args: &Args) -> Result<()> {
    let rt = Runtime::builder()
        .artifacts_dir(artifacts_dir(args))
        .kernel(kernel_from_args(args)?)
        .build()?;
    let model = args.get_or("model", "mnist").to_string();
    let ae_tag = args.get_or("ae", &model).to_string();
    let pipeline = AePipeline::new(&rt, &ae_tag)?;
    let mut cfg = ExperimentConfig::default();
    cfg.model = model.clone();
    cfg.prepass.epochs = args.get_usize("epochs", 30)?;
    cfg.prepass.ae_epochs = args.get_usize("ae-epochs", 25)?;
    cfg.seed = args.get_u64("seed", 1)?;

    let kind = if model == "mnist" {
        fedae::data::SynthKind::Mnist
    } else {
        fedae::data::SynthKind::Cifar
    };
    let (shards, test) = fedae::data::make_shards(
        kind,
        fedae::config::Sharding::Iid,
        0.5,
        1,
        args.get_usize("per-collab", 2048)?,
        512,
        cfg.seed,
    )?;
    let init = rt.load_init(&format!("{model}_params"))?;
    let ae_init = rt.load_init(&format!("ae_{ae_tag}_init"))?;
    println!(
        "prepass: model={model} ({} params), AE={ae_tag} ({} params, latent {})",
        init.len(),
        pipeline.n_params,
        pipeline.latent
    );
    let pp = fedae::collaborator::run_prepass(
        &rt,
        &model,
        &pipeline,
        &shards[0],
        &cfg.prepass,
        &cfg.train,
        &init,
        &ae_init,
        cfg.seed,
    )?;
    let mse_series: Vec<(usize, f64)> = pp
        .ae_history
        .iter()
        .enumerate()
        .map(|(i, (mse, _))| (i, *mse as f64))
        .collect();
    let acc_series: Vec<(usize, f64)> = pp
        .ae_history
        .iter()
        .enumerate()
        .map(|(i, (_, acc))| (i, *acc as f64))
        .collect();
    println!(
        "{}",
        ascii_plot("AE training accuracy (Fig 4/6)", &[("acc", &acc_series)], 60, 12)
    );
    println!(
        "{}",
        ascii_plot("AE training MSE", &[("mse", &mse_series)], 60, 12)
    );
    let val = fedae::collaborator::validation_model(
        &rt,
        &model,
        &pipeline,
        &pp.ae_params,
        &pp.snapshots,
        pp.n_snapshots,
        &test,
    )?;
    let rows: Vec<Vec<String>> = val
        .iter()
        .map(|p| {
            vec![
                p.snapshot.to_string(),
                format!("{:.4}", p.orig_acc),
                format!("{:.4}", p.recon_acc),
                format!("{:.2e}", p.weight_mse),
            ]
        })
        .collect();
    println!(
        "{}",
        print_table(&["snapshot", "orig_acc", "ae_acc", "weight_mse"], &rows)
    );
    Ok(())
}

fn cmd_savings(args: &Args) -> Result<()> {
    let model: SavingsModel = if args.flag("mnist") {
        fedae::savings::REPO_MNIST
    } else {
        PAPER_CIFAR
    };
    let rounds = args.get_usize("rounds", 100)?;
    let max_collabs = args.get_usize("max-collabs", 2000)?;
    println!(
        "savings model: orig={} comp={} ae={} (ratio {:.1}x)",
        model.original_size,
        model.compressed_size,
        model.autoencoder_size,
        model.compression_ratio()
    );
    let grid: Vec<usize> = (0..)
        .map(|i| 1usize << i)
        .take_while(|&c| c <= max_collabs)
        .chain([max_collabs])
        .collect();
    let sweep = model.sweep_collabs(rounds, &grid)?;
    let series: Vec<(usize, f64)> = sweep.clone();
    println!(
        "{}",
        ascii_plot(
            &format!("Fig 10: savings ratio vs collaborators (single decoder, R={rounds})"),
            &[("SR", &series)],
            64,
            14
        )
    );
    println!(
        "break-even (case a): {} collaborators at R={rounds}",
        model.breakeven_collabs_single_decoder(rounds)?
    );
    println!(
        "break-even (case b): {} rounds (independent of collaborators)",
        model.breakeven_rounds_per_collab_decoders()?
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let rt = Runtime::from_dir(artifacts_dir(args))?;
    let m = rt.manifest();
    println!("platform: {}", rt.platform_name());
    let rows: Vec<Vec<String>> = m
        .models
        .iter()
        .map(|(name, e)| {
            vec![
                name.clone(),
                e.n_params.to_string(),
                e.input_dim.to_string(),
                e.train_batch.to_string(),
            ]
        })
        .collect();
    println!("{}", print_table(&["model", "params", "input", "batch"], &rows));
    let rows: Vec<Vec<String>> = m
        .autoencoders
        .iter()
        .map(|(name, e)| {
            vec![
                name.clone(),
                format!("{:?}", e.dims),
                e.n_params.to_string(),
                format!("{:.1}x", e.compression_ratio),
            ]
        })
        .collect();
    println!(
        "{}",
        print_table(&["autoencoder", "dims", "params", "ratio"], &rows)
    );
    println!("artifacts: {}", m.artifacts.len());
    Ok(())
}

// ---------------------------------------------------------------------------
// Multi-process protocol mode (full pipeline over TCP)
// ---------------------------------------------------------------------------

/// Coordinator: run the full federated pipeline — any compression
/// scheme (AE latents + decoder shipment included), any aggregator,
/// seeded selection — over real TCP sockets via the message-driven
/// [`fedae::coordinator::ProtocolServer`]. On the same config this
/// produces bitwise-identical final params and ledger byte totals to
/// `fedae train` (the in-process simulator).
fn fedae_serve(args: &Args) -> Result<()> {
    use fedae::coordinator::{ProtocolServer, TcpAcceptor};

    let cfg = config_from_args(args)?;
    let port = args.get_usize("port", 7070)?;
    let rt = Runtime::builder()
        .artifacts_dir(artifacts_dir(args))
        .kernel(cfg.backend.kernel)
        .step_parallelism(cfg.engine.step_parallelism)
        .build()?;
    let pipeline;
    let pipe_ref = match &cfg.compression {
        CompressionConfig::Ae { ae } => {
            pipeline = AePipeline::new(&rt, ae)?;
            Some(&pipeline)
        }
        _ => None,
    };
    let mut acceptor = TcpAcceptor::bind(("0.0.0.0", port as u16), cfg.protocol.max_frame_bytes)?;
    println!(
        "coordinator: model={} compression={} rounds={} collabs={} min_participants={} quorum={}",
        cfg.model,
        cfg.compression.kind_name(),
        cfg.fl.rounds,
        cfg.fl.collaborators,
        cfg.protocol.resolve_min_participants(cfg.fl.collaborators),
        cfg.protocol.quorum,
    );
    // A parseable, flushed line the process-level chaos harness waits
    // for before spawning workers (also resolves `--port 0` binds).
    {
        use std::io::Write;
        println!("listening on {}", acceptor.local_addr()?);
        std::io::stdout().flush()?;
    }
    let mut server = ProtocolServer::new(&rt, cfg, pipe_ref)?;
    server.set_round_logging(true);
    let report = server.run(&mut acceptor)?;
    for (round, cid) in &report.evictions {
        println!("evicted: collaborator {cid} in round {round}");
    }
    for (round, survivors) in &report.quorum_stalls {
        println!("stalled: round {round} closed with only {survivors} survivors, retried");
    }
    let totals = &report.ledger_totals;
    println!(
        "done: state={} total_bytes={} update_uploads={} dedup_hits={} rejected_frames={} \
         rejoins={} conn_drops={} quorum_stalls={}",
        server.state(),
        totals.total_bytes,
        totals.update_up_count,
        report.dedup_hits,
        report.rejected_frames,
        report.rejoins,
        report.conn_drops,
        report.quorum_stalls.len(),
    );
    Ok(())
}

/// Worker: connect to the coordinator and run the full collaborator
/// loop — lazy activation (AE pre-pass + decoder shipment on first
/// selection), local training, compressed uploads, eval reports, and
/// idle heartbeats — until the coordinator sends `Shutdown`. The config
/// flags must match the coordinator's.
fn fedae_worker(args: &Args) -> Result<()> {
    use fedae::coordinator::run_worker;
    use fedae::transport::retry::{DialFn, ReconnectingTransport, RetryPolicy};
    use fedae::transport::{TcpTransport, Transport};

    let cfg = config_from_args(args)?;
    let addr = args
        .get("connect")
        .ok_or("worker needs --connect HOST:PORT")?;
    let id = args.get_usize("id", 0)?;
    let rt = Runtime::builder()
        .artifacts_dir(artifacts_dir(args))
        .kernel(cfg.backend.kernel)
        .step_parallelism(cfg.engine.step_parallelism)
        .build()?;
    let pipeline;
    let pipe_ref = match &cfg.compression {
        CompressionConfig::Ae { ae } => {
            pipeline = AePipeline::new(&rt, ae)?;
            Some(&pipeline)
        }
        _ => None,
    };
    // Redial-on-disconnect transport: a dead socket is re-established
    // under the retry policy and re-enters the federation with Rejoin,
    // so a worker survives a coordinator-side drop (or its own crash
    // window) without restarting from Hello.
    let dial_addr = addr.to_string();
    let max_frame = cfg.protocol.max_frame_bytes;
    let dial: DialFn = Box::new(move || {
        let mut t = TcpTransport::connect(&dial_addr)?;
        t.set_max_frame(max_frame);
        t.set_write_timeout(Some(std::time::Duration::from_secs(30)))?;
        Ok(Box::new(t) as Box<dyn Transport>)
    });
    let policy = RetryPolicy::from_protocol(&cfg.protocol, cfg.seed ^ id as u64);
    let mut transport = ReconnectingTransport::new(dial, policy);
    println!("worker {id}: dialing {addr}");
    let report = run_worker(&rt, &cfg, pipe_ref, id, &mut transport)?;
    println!(
        "worker {id}: shutdown after {} rounds ({} data bytes up, {} heartbeats, \
         {} reconnects, {} catch_ups, {} resends)",
        report.rounds_participated,
        report.bytes_up,
        report.heartbeats_sent,
        transport.reconnects(),
        report.catch_ups,
        report.resends,
    );
    Ok(())
}
