//! Analytic savings model — paper §5.3, Eq. 4–6 and Figs 10/11.
//!
//! ```text
//!          OriginalSize x CommRounds x Collabs
//! SR = ------------------------------------------------     (Eq. 4)
//!       CompressedSize x CommRounds x Collabs + Cost
//!
//! Cost = DecoderSize x No.ofDecoders                          (Eq. 5)
//!      = AutoencoderSize / 2 x No.ofDecoders                  (Eq. 6)
//! ```
//!
//! Two regimes from the paper:
//! * **Case (a)** one decoder serves the whole federation → SR grows with
//!   the number of collaborators (Fig 10: break-even ≈ 40 collaborators at
//!   R = 100, asymptote ≈ 120x beyond 1000 collaborators).
//! * **Case (b)** one decoder per collaborator → collaborators cancel and
//!   SR depends only on rounds (Fig 11: break-even at R = 320).
//!
//! The constants below are the paper's own (550,570-param CIFAR classifier,
//! 352,915,690-param FC AE, 1720x), used verbatim since Eq. 4–6 are closed
//! form — see DESIGN.md §3.

use crate::error::{FedAeError, Result};

/// Parameters of the savings model (sizes in *parameters*; everything is a
/// ratio so the 4-bytes-per-f32 factor cancels).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SavingsModel {
    /// Uncompressed update size (model parameter count).
    pub original_size: f64,
    /// Compressed update size (latent dimension).
    pub compressed_size: f64,
    /// AE parameter count; decoder cost is half of it (Eq. 6).
    pub autoencoder_size: f64,
}

/// Paper constants for the CIFAR-scale analysis (§5.3).
pub const PAPER_CIFAR: SavingsModel = SavingsModel {
    original_size: 550_570.0,
    compressed_size: 320.0, // 550570 / 320 = 1720.5x
    autoencoder_size: 352_915_690.0,
};

/// Constants for this repo's MNIST-scale AE (~500x).
pub const REPO_MNIST: SavingsModel = SavingsModel {
    original_size: 15_910.0,
    compressed_size: 32.0,
    autoencoder_size: 1_034_182.0,
};

impl SavingsModel {
    /// Decoder cost in parameters (Eq. 5/6).
    pub fn decoder_cost(&self, n_decoders: usize) -> f64 {
        self.autoencoder_size / 2.0 * n_decoders as f64
    }

    /// Per-update compression ratio (no amortized decoder cost).
    pub fn compression_ratio(&self) -> f64 {
        self.original_size / self.compressed_size
    }

    /// Eq. 4 with an explicit decoder count.
    pub fn savings_ratio(&self, rounds: usize, collabs: usize, n_decoders: usize) -> Result<f64> {
        if rounds == 0 || collabs == 0 {
            return Err(FedAeError::Config(
                "savings_ratio: rounds/collabs must be > 0".into(),
            ));
        }
        let rc = rounds as f64 * collabs as f64;
        let denom = self.compressed_size * rc + self.decoder_cost(n_decoders);
        Ok(self.original_size * rc / denom)
    }

    /// Case (a): a single decoder for the whole federation (Fig 10).
    pub fn savings_ratio_single_decoder(&self, rounds: usize, collabs: usize) -> Result<f64> {
        self.savings_ratio(rounds, collabs, 1)
    }

    /// Case (b): one decoder per collaborator (Fig 11). Collaborator count
    /// cancels out of Eq. 4 in this case.
    pub fn savings_ratio_per_collab_decoders(
        &self,
        rounds: usize,
        collabs: usize,
    ) -> Result<f64> {
        self.savings_ratio(rounds, collabs, collabs)
    }

    /// Asymptotic SR as rounds x collabs -> infinity: the raw compression
    /// ratio (decoder cost amortizes away)... but for finite rounds in
    /// case (a) the asymptote over collaborators is lower:
    /// SR -> orig*R / (comp*R + 0) as C -> inf only if cost stays fixed;
    /// with cost fixed the limit is orig/comp. The *finite-R* plateau the
    /// paper quotes (≈120x at R=100) is really SR at large C:
    ///   SR(C) = orig*R*C / (comp*R*C + cost) -> orig/comp as C->inf,
    /// approached slowly; at C=1000, R=100 it is ≈ 120x. Use
    /// [`Self::savings_ratio`] for exact values.
    pub fn asymptotic_ratio(&self) -> f64 {
        self.compression_ratio()
    }

    /// Break-even collaborator count for case (a): smallest C with SR >= 1
    /// at fixed `rounds`. Solved in closed form from Eq. 4:
    ///   C >= cost / (R * (orig - comp)).
    pub fn breakeven_collabs_single_decoder(&self, rounds: usize) -> Result<usize> {
        if self.original_size <= self.compressed_size {
            return Err(FedAeError::Config(
                "no break-even: compression does not save bytes".into(),
            ));
        }
        let c = self.decoder_cost(1) / (rounds as f64 * (self.original_size - self.compressed_size));
        Ok(c.ceil().max(1.0) as usize)
    }

    /// Break-even round count for case (b): smallest R with SR >= 1.
    ///   R >= (cost/C) / (orig - comp)  — independent of C since cost ∝ C.
    pub fn breakeven_rounds_per_collab_decoders(&self) -> Result<usize> {
        if self.original_size <= self.compressed_size {
            return Err(FedAeError::Config(
                "no break-even: compression does not save bytes".into(),
            ));
        }
        let r = (self.autoencoder_size / 2.0) / (self.original_size - self.compressed_size);
        Ok(r.ceil().max(1.0) as usize)
    }

    /// Fig 10 series: SR vs collaborator count, single decoder.
    pub fn sweep_collabs(
        &self,
        rounds: usize,
        collab_grid: &[usize],
    ) -> Result<Vec<(usize, f64)>> {
        collab_grid
            .iter()
            .map(|&c| Ok((c, self.savings_ratio_single_decoder(rounds, c)?)))
            .collect()
    }

    /// Fig 11 series: SR vs rounds, per-collaborator decoders.
    pub fn sweep_rounds(
        &self,
        collabs: usize,
        round_grid: &[usize],
    ) -> Result<Vec<(usize, f64)>> {
        round_grid
            .iter()
            .map(|&r| Ok((r, self.savings_ratio_per_collab_decoders(r, collabs)?)))
            .collect()
    }
}

/// Build a [`SavingsModel`] from measured quantities (n params, latent,
/// AE size) — used to cross-check the analytic model against the ledger.
pub fn from_measured(n_params: usize, latent: usize, ae_params: usize) -> SavingsModel {
    SavingsModel {
        original_size: n_params as f64,
        compressed_size: latent as f64,
        autoencoder_size: ae_params as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// NOTE on Fig 10 (documented in EXPERIMENTS.md): the paper's two
    /// quoted Fig-10 landmarks — break-even at 40 collaborators AND SR ~=
    /// 120x at 1000 collaborators — are mutually inconsistent under the
    /// paper's own Eq. 4 for ANY single round count R:
    ///   break-even C=40  requires R ~= 8,
    ///   SR(1000) = 120x  requires R ~= 41.
    /// We therefore verify each landmark at the R that produces it, plus
    /// the model's internal consistency (brute-force vs closed form).
    #[test]
    fn paper_fig10_breakeven_is_about_40_collabs_at_r8() {
        let be = PAPER_CIFAR.breakeven_collabs_single_decoder(8).unwrap();
        assert!(
            (38..=42).contains(&be),
            "break-even {be} not near the paper's ~40 (R=8)"
        );
    }

    #[test]
    fn paper_fig10_sr_about_120x_at_1000_collabs_r41() {
        let sr = PAPER_CIFAR.savings_ratio_single_decoder(41, 1000).unwrap();
        assert!((110.0..130.0).contains(&sr), "SR(1000, R=41) = {sr}");
    }

    #[test]
    fn breakeven_closed_form_matches_brute_force() {
        for rounds in [1usize, 8, 41, 100, 1000] {
            let be = PAPER_CIFAR
                .breakeven_collabs_single_decoder(rounds)
                .unwrap();
            let sr_at = PAPER_CIFAR.savings_ratio_single_decoder(rounds, be).unwrap();
            assert!(sr_at >= 1.0, "R={rounds}: SR({be}) = {sr_at} < 1");
            if be > 1 {
                let sr_below = PAPER_CIFAR
                    .savings_ratio_single_decoder(rounds, be - 1)
                    .unwrap();
                assert!(sr_below < 1.0, "R={rounds}: SR({}) = {sr_below} >= 1", be - 1);
            }
        }
    }

    #[test]
    fn paper_fig11_breakeven_at_320_rounds() {
        // Paper: "Breakeven point when No. of Comm rounds = 320".
        let be = PAPER_CIFAR.breakeven_rounds_per_collab_decoders().unwrap();
        assert!(
            (315..=325).contains(&be),
            "break-even {be} not near the paper's 320"
        );
        // SR crosses 1.0 exactly there.
        let below = PAPER_CIFAR
            .savings_ratio_per_collab_decoders(be - 1, 7)
            .unwrap();
        let above = PAPER_CIFAR
            .savings_ratio_per_collab_decoders(be, 7)
            .unwrap();
        assert!(below < 1.0 && above >= 1.0, "below={below} above={above}");
    }

    #[test]
    fn case_b_is_independent_of_collaborators() {
        for c in [1usize, 10, 1000] {
            let sr = PAPER_CIFAR.savings_ratio_per_collab_decoders(500, c).unwrap();
            let sr1 = PAPER_CIFAR.savings_ratio_per_collab_decoders(500, 1).unwrap();
            assert!((sr - sr1).abs() < 1e-9, "C={c}: {sr} vs {sr1}");
        }
    }

    #[test]
    fn sr_monotone_in_collabs_case_a() {
        let mut prev = 0.0;
        for c in [1usize, 10, 100, 1000, 10_000] {
            let sr = PAPER_CIFAR.savings_ratio_single_decoder(100, c).unwrap();
            assert!(sr > prev, "SR must grow with collaborators");
            prev = sr;
        }
        // And approaches (never exceeds) the pure compression ratio.
        assert!(prev < PAPER_CIFAR.compression_ratio());
    }

    #[test]
    fn compression_ratios_match_paper() {
        assert!((PAPER_CIFAR.compression_ratio() - 1720.5).abs() < 0.1);
        assert!((REPO_MNIST.compression_ratio() - 497.2).abs() < 0.1);
    }

    #[test]
    fn decoder_cost_eq6() {
        assert_eq!(PAPER_CIFAR.decoder_cost(1), 352_915_690.0 / 2.0);
        assert_eq!(PAPER_CIFAR.decoder_cost(4), 352_915_690.0 * 2.0);
    }

    #[test]
    fn sweeps_match_pointwise_eval() {
        let grid = [1usize, 40, 100, 1000];
        let sweep = PAPER_CIFAR.sweep_collabs(100, &grid).unwrap();
        for (c, sr) in sweep {
            let direct = PAPER_CIFAR.savings_ratio_single_decoder(100, c).unwrap();
            assert!((sr - direct).abs() < 1e-12);
        }
        let rsweep = PAPER_CIFAR.sweep_rounds(2, &[321, 640]).unwrap();
        assert!(rsweep[0].1 >= 1.0 && rsweep[1].1 > rsweep[0].1);
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(PAPER_CIFAR.savings_ratio(0, 10, 1).is_err());
        assert!(PAPER_CIFAR.savings_ratio(10, 0, 1).is_err());
        let no_gain = SavingsModel {
            original_size: 10.0,
            compressed_size: 20.0,
            autoencoder_size: 100.0,
        };
        assert!(no_gain.breakeven_collabs_single_decoder(10).is_err());
        assert!(no_gain.breakeven_rounds_per_collab_decoders().is_err());
    }

    #[test]
    fn from_measured_matches_manifest_numbers() {
        let m = from_measured(15_910, 32, 1_034_182);
        assert_eq!(m.original_size, REPO_MNIST.original_size);
        assert!((m.compression_ratio() - 497.1875).abs() < 1e-9);
    }
}
