//! No-op stand-in for the `xla` crate (xla-rs / PJRT bindings).
//!
//! The fedae workspace must build fully offline with zero registry
//! dependencies, so the real xla-rs crate cannot be a hard requirement.
//! This stub mirrors exactly the API surface `fedae::backend::xla` uses,
//! letting `cargo check/build/clippy --features xla` succeed everywhere;
//! every runtime entry point returns a descriptive [`Error`] instructing
//! the user to swap in the real bindings.
//!
//! To enable the actual PJRT fast path, point the `xla` dependency in
//! `rust/Cargo.toml` at a checkout of xla-rs (same API) and rebuild with
//! `--features xla`; no fedae source changes are needed.

use std::fmt;

/// Error type matching xla-rs's `xla::Error` usage (`Display` + `Debug`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_error() -> Error {
    Error(
        "fedae was built against the bundled no-op `xla` stub; point the `xla` \
         dependency in rust/Cargo.toml at a real xla-rs checkout to run the \
         PJRT fast path (see README, section `XLA backend`)"
            .to_string(),
    )
}

/// PJRT client handle (stub: cannot be constructed).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_error())
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_error())
    }
}

/// Compiled executable handle (stub: never exists at runtime).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_error())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_error())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_error())
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub: carries no data; construction succeeds so callers
/// can build argument lists, execution fails first).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_error())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(stub_error())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_with_guidance() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("xla-rs"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
