//! Bench — server aggregation cost: sequential batch vs streaming
//! accumulators vs streaming + parallel shards (ISSUE 4 acceptance: the
//! streaming path's peak buffered floats are bounded by the accumulator
//! + in-flight model — independent of the participant count — while the
//! batch paths scale with `participants x shard_size` or
//! `participants x n`; outcomes stay bitwise identical), plus the
//! decoder-level batched decode the coordinator uses for duplicate-cid
//! rounds (ISSUE 9: `decompress_batch` runs B latents as one
//! `[B, latent]` GEMM chain, bitwise-equal to B separate decodes).
//!
//! Per federation size this runs the same fixed-seed experiment three
//! ways — `agg_path = "batch"` (sequential, sharded), `"stream"`
//! (sequential), and `"stream"` with all-core shard workers — and
//! reports per-round server aggregation time, peak buffered floats, and
//! the decode meter readings (full/range/batched decodes), all read from
//! `RoundOutcome::agg`, the same source of truth as the CLI log fields.
//!
//! Besides the tables, the run writes machine-readable results to
//! `BENCH_streaming_agg.json` in the working directory.
//!
//! `cargo bench --bench bench_streaming_agg`
//! (set `FEDAE_BENCH_MAX_COLLABS=1024` for the largest tier; default 256
//! keeps a full run in laptop territory.)

use fedae::config::{AggPath, AggregationConfig, CompressionConfig, EngineConfig, ExperimentConfig};
use fedae::coordinator::{AggRoundStats, FlDriver, RoundOutcome};
use fedae::metrics::print_table;
use fedae::runtime::{AePipeline, Runtime};
use fedae::util::bench_timings;
use fedae::util::json::Json;

/// MNIST classifier parameter count (fixed by the manifest).
const N: u64 = 15_910;
const SHARD: usize = 4096;

fn cfg_for(collabs: usize, engine: EngineConfig) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_streaming_agg_{collabs}");
    cfg.model = "mnist".into();
    // Identity compression keeps setup cheap at 1024 collaborators (no
    // pre-pass) while still pushing `participants x n` floats through
    // the server; decode counts for the dense schemes differ only by
    // the metered classification (see rust/tests/streaming_agg.rs).
    cfg.compression = CompressionConfig::Identity;
    cfg.aggregation = AggregationConfig::FedAvg;
    cfg.fl.collaborators = collabs;
    cfg.fl.rounds = 8; // driver cap; we time fewer below
    cfg.fl.local_epochs = 1;
    cfg.data.per_collab = 64;
    cfg.data.test_size = 128;
    cfg.seed = 31;
    cfg.engine = engine;
    cfg
}

struct Run {
    outcomes: Vec<RoundOutcome>,
    global: Vec<f32>,
    /// Mean per-round aggregation wall time (ms) + summed meter.
    agg_ms: f64,
    agg: AggRoundStats,
}

fn run(
    rt: &Runtime,
    collabs: usize,
    engine: EngineConfig,
    rounds: usize,
) -> fedae::error::Result<Run> {
    let mut driver = FlDriver::builder(rt, cfg_for(collabs, engine)).build()?;
    let mut outcomes = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        outcomes.push(driver.run_round()?);
    }
    let mut agg = AggRoundStats::default();
    for o in &outcomes {
        agg.accumulate(&o.agg);
    }
    Ok(Run {
        agg_ms: agg.ms / rounds as f64,
        global: driver.global_params().to_vec(),
        outcomes,
        agg,
    })
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() -> fedae::error::Result<()> {
    let rt = Runtime::from_dir("artifacts")?;
    let workers = fedae::coordinator::ParallelRoundEngine::new(0).workers();
    let max_collabs: usize = std::env::var("FEDAE_BENCH_MAX_COLLABS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    println!(
        "== streaming aggregation, synth-mnist (n={N}), shard_size={SHARD}, {workers} workers =="
    );
    let mut json_agg = Vec::new();
    let mut json_decode = Vec::new();

    let mut rows = Vec::new();
    for collabs in [64, 256, 1024] {
        if collabs > max_collabs {
            println!("(skipping {collabs} collaborators; raise FEDAE_BENCH_MAX_COLLABS)");
            continue;
        }
        let rounds = if collabs >= 1024 { 2 } else { 3 };
        let batch = EngineConfig {
            shard_size: SHARD,
            agg_path: AggPath::Batch,
            ..EngineConfig::default()
        };
        let stream = EngineConfig {
            shard_size: SHARD,
            agg_path: AggPath::Stream,
            ..EngineConfig::default()
        };
        let stream_par = EngineConfig {
            parallelism: 0,
            shard_size: SHARD,
            agg_path: AggPath::Stream,
            ..EngineConfig::default()
        };
        let b = run(&rt, collabs, batch, rounds)?;
        let s = run(&rt, collabs, stream, rounds)?;
        let p = run(&rt, collabs, stream_par, rounds)?;

        // The whole point: the aggregation path changes decode counts,
        // memory and wall-clock — never results.
        assert_eq!(b.outcomes, s.outcomes, "stream outcomes diverged at {collabs}");
        assert_eq!(b.global, s.global, "stream params diverged at {collabs}");
        assert_eq!(b.outcomes, p.outcomes, "parallel outcomes diverged at {collabs}");
        assert_eq!(b.global, p.global, "parallel params diverged at {collabs}");

        // The memory story (the deterministic cost model the driver
        // reports): batch buffers participants x shard_size; streaming
        // buffers the accumulators + a bounded number of in-flight
        // reconstructions, independent of participants.
        let m = b.outcomes[0].stragglers.admitted as u64;
        assert_eq!(b.agg.peak_floats, m * SHARD as u64);
        assert_eq!(s.agg.peak_floats, 2 * N);
        assert!(p.agg.peak_floats <= 4 * N);
        // One full decode per update per round on the streaming path;
        // sync rounds never repeat a cid, so nothing groups into a batch.
        assert_eq!(s.agg.full_decodes, m * rounds as u64);
        assert_eq!(s.agg.range_decodes, 0);
        assert_eq!(s.agg.batched_decodes, 0);

        for (label, r) in [("batch", &b), ("stream", &s), ("stream+par", &p)] {
            rows.push(vec![
                collabs.to_string(),
                label.to_string(),
                format!("{:.1}", r.agg_ms),
                r.agg.peak_floats.to_string(),
                (r.agg.full_decodes / rounds as u64).to_string(),
                (r.agg.range_decodes / rounds as u64).to_string(),
                (r.agg.batched_decodes / rounds as u64).to_string(),
            ]);
            json_agg.push(obj(vec![
                ("collaborators", Json::Num(collabs as f64)),
                ("agg_path", Json::Str(label.to_string())),
                ("agg_ms_per_round", Json::Num(r.agg_ms)),
                ("peak_floats", Json::Num(r.agg.peak_floats as f64)),
                ("full_decodes", Json::Num(r.agg.full_decodes as f64)),
                ("range_decodes", Json::Num(r.agg.range_decodes as f64)),
                ("batched_decodes", Json::Num(r.agg.batched_decodes as f64)),
            ]));
        }
    }
    println!(
        "{}",
        print_table(
            &[
                "collaborators",
                "agg path",
                "agg ms/round",
                "peak buffered floats",
                "full decodes/round",
                "range decodes/round",
                "batched decodes/round"
            ],
            &rows
        )
    );
    println!("(outcomes verified bitwise-identical across all three paths)");

    // --- decoder-level batched decode (what duplicate-cid rounds hit) -----
    // B latents through the mnist AE decoder: one `[B, latent]` GEMM
    // chain vs B single-row decodes. The batched path must be bitwise
    // identical; the win is amortizing the decoder-weight traffic
    // (32 -> 15910 is heavily memory-bound at m = 1).
    let pipe = AePipeline::new(&rt, "mnist")?;
    let ae = rt.load_init("ae_mnist_init")?;
    let (_, dec) = pipe.split(&ae)?;
    let mut rows = Vec::new();
    for batch in [64usize, 256] {
        let zs: Vec<Vec<f32>> = (0..batch)
            .map(|r| {
                (0..pipe.latent)
                    .map(|i| ((r * pipe.latent + i) as f32 * 0.17).sin() * 0.3)
                    .collect()
            })
            .collect();
        let refs: Vec<&[f32]> = zs.iter().map(|z| z.as_slice()).collect();
        let mut looped = Vec::new();
        let (loop_ms, _, _) = bench_timings(1, 5, || {
            looped = zs.iter().map(|z| pipe.decode(&dec, z).unwrap()).collect();
        });
        let mut batched: Vec<Vec<f32>> = Vec::new();
        let (batch_ms, _, _) = bench_timings(1, 5, || {
            batched = pipe.decode_batch(&dec, &refs).unwrap();
        });
        assert_eq!(looped, batched, "batched decode diverged at B={batch}");
        rows.push(vec![
            batch.to_string(),
            format!("{loop_ms:.2}"),
            format!("{batch_ms:.2}"),
            format!("{:.2}x", loop_ms / batch_ms),
        ]);
        json_decode.push(obj(vec![
            ("batch", Json::Num(batch as f64)),
            ("loop_ms", Json::Num(loop_ms)),
            ("batched_ms", Json::Num(batch_ms)),
            ("speedup", Json::Num(loop_ms / batch_ms)),
        ]));
    }
    println!(
        "{}",
        print_table(
            &["decode batch B", "looped ms", "batched ms", "speedup"],
            &rows
        )
    );
    println!("(batched rows verified bitwise-identical to per-latent decodes)");

    let doc = obj(vec![
        ("bench", Json::Str("streaming_agg".to_string())),
        ("n", Json::Num(N as f64)),
        ("shard_size", Json::Num(SHARD as f64)),
        ("workers", Json::Num(workers as f64)),
        ("aggregation", Json::Arr(json_agg)),
        ("batched_decode", Json::Arr(json_decode)),
    ]);
    std::fs::write("BENCH_streaming_agg.json", doc.to_string_pretty())?;
    println!("machine-readable results written to BENCH_streaming_agg.json");
    Ok(())
}
