//! Bench — server aggregation cost: sequential batch vs streaming
//! accumulators vs streaming + parallel shards (ISSUE 4 acceptance: the
//! streaming path's peak buffered floats are bounded by the accumulator
//! + in-flight model — independent of the participant count — while the
//! batch paths scale with `participants x shard_size` or
//! `participants x n`; outcomes stay bitwise identical).
//!
//! Per federation size this runs the same fixed-seed experiment three
//! ways — `agg_path = "batch"` (sequential, sharded), `"stream"`
//! (sequential), and `"stream"` with all-core shard workers — and
//! reports per-round server aggregation time, peak buffered floats, and
//! the decode meter readings (full/range decodes), all read from
//! `RoundOutcome::agg`, the same source of truth as the CLI log fields.
//!
//! `cargo bench --bench bench_streaming_agg`
//! (set `FEDAE_BENCH_MAX_COLLABS=1024` for the largest tier; default 256
//! keeps a full run in laptop territory.)

use fedae::config::{AggPath, AggregationConfig, CompressionConfig, EngineConfig, ExperimentConfig};
use fedae::coordinator::{AggRoundStats, FlDriver, RoundOutcome};
use fedae::metrics::print_table;
use fedae::runtime::Runtime;

/// MNIST classifier parameter count (fixed by the manifest).
const N: u64 = 15_910;
const SHARD: usize = 4096;

fn cfg_for(collabs: usize, engine: EngineConfig) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_streaming_agg_{collabs}");
    cfg.model = "mnist".into();
    // Identity compression keeps setup cheap at 1024 collaborators (no
    // pre-pass) while still pushing `participants x n` floats through
    // the server; decode counts for the dense schemes differ only by
    // the metered classification (see rust/tests/streaming_agg.rs).
    cfg.compression = CompressionConfig::Identity;
    cfg.aggregation = AggregationConfig::FedAvg;
    cfg.fl.collaborators = collabs;
    cfg.fl.rounds = 8; // driver cap; we time fewer below
    cfg.fl.local_epochs = 1;
    cfg.data.per_collab = 64;
    cfg.data.test_size = 128;
    cfg.seed = 31;
    cfg.engine = engine;
    cfg
}

struct Run {
    outcomes: Vec<RoundOutcome>,
    global: Vec<f32>,
    /// Mean per-round aggregation wall time (ms) + summed meter.
    agg_ms: f64,
    agg: AggRoundStats,
}

fn run(
    rt: &Runtime,
    collabs: usize,
    engine: EngineConfig,
    rounds: usize,
) -> fedae::error::Result<Run> {
    let mut driver = FlDriver::builder(rt, cfg_for(collabs, engine)).build()?;
    let mut outcomes = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        outcomes.push(driver.run_round()?);
    }
    let mut agg = AggRoundStats::default();
    for o in &outcomes {
        agg.accumulate(&o.agg);
    }
    Ok(Run {
        agg_ms: agg.ms / rounds as f64,
        global: driver.global_params().to_vec(),
        outcomes,
        agg,
    })
}

fn main() -> fedae::error::Result<()> {
    let rt = Runtime::from_dir("artifacts")?;
    let workers = fedae::coordinator::ParallelRoundEngine::new(0).workers();
    let max_collabs: usize = std::env::var("FEDAE_BENCH_MAX_COLLABS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    println!(
        "== streaming aggregation, synth-mnist (n={N}), shard_size={SHARD}, {workers} workers =="
    );

    let mut rows = Vec::new();
    for collabs in [64, 256, 1024] {
        if collabs > max_collabs {
            println!("(skipping {collabs} collaborators; raise FEDAE_BENCH_MAX_COLLABS)");
            continue;
        }
        let rounds = if collabs >= 1024 { 2 } else { 3 };
        let batch = EngineConfig {
            shard_size: SHARD,
            agg_path: AggPath::Batch,
            ..EngineConfig::default()
        };
        let stream = EngineConfig {
            shard_size: SHARD,
            agg_path: AggPath::Stream,
            ..EngineConfig::default()
        };
        let stream_par = EngineConfig {
            parallelism: 0,
            shard_size: SHARD,
            agg_path: AggPath::Stream,
            ..EngineConfig::default()
        };
        let b = run(&rt, collabs, batch, rounds)?;
        let s = run(&rt, collabs, stream, rounds)?;
        let p = run(&rt, collabs, stream_par, rounds)?;

        // The whole point: the aggregation path changes decode counts,
        // memory and wall-clock — never results.
        assert_eq!(b.outcomes, s.outcomes, "stream outcomes diverged at {collabs}");
        assert_eq!(b.global, s.global, "stream params diverged at {collabs}");
        assert_eq!(b.outcomes, p.outcomes, "parallel outcomes diverged at {collabs}");
        assert_eq!(b.global, p.global, "parallel params diverged at {collabs}");

        // The memory story (the deterministic cost model the driver
        // reports): batch buffers participants x shard_size; streaming
        // buffers the accumulators + a bounded number of in-flight
        // reconstructions, independent of participants.
        let m = b.outcomes[0].stragglers.admitted as u64;
        assert_eq!(b.agg.peak_floats, m * SHARD as u64);
        assert_eq!(s.agg.peak_floats, 2 * N);
        assert!(p.agg.peak_floats <= 4 * N);
        // One full decode per update per round on the streaming path.
        assert_eq!(s.agg.full_decodes, m * rounds as u64);
        assert_eq!(s.agg.range_decodes, 0);

        for (label, r) in [("batch", &b), ("stream", &s), ("stream+par", &p)] {
            rows.push(vec![
                collabs.to_string(),
                label.to_string(),
                format!("{:.1}", r.agg_ms),
                r.agg.peak_floats.to_string(),
                (r.agg.full_decodes / rounds as u64).to_string(),
                (r.agg.range_decodes / rounds as u64).to_string(),
            ]);
        }
    }
    println!(
        "{}",
        print_table(
            &[
                "collaborators",
                "agg path",
                "agg ms/round",
                "peak buffered floats",
                "full decodes/round",
                "range decodes/round"
            ],
            &rows
        )
    );
    println!("(outcomes verified bitwise-identical across all three paths)");
    Ok(())
}
