//! Bench — parallel round engine wall-clock speedup at large federation
//! sizes (ISSUE 2 acceptance: >= 2x over the sequential driver at 256
//! simulated collaborators on a multi-core runner, with identical
//! fixed-seed outcomes).
//!
//! Per federation size this times the same fixed-seed experiment three
//! ways — sequential (`parallelism=1`), parallel (`parallelism=0`, one
//! worker per core), and parallel + sharded aggregation — and asserts the
//! round outcomes and final global parameters are bitwise identical
//! before reporting the speedup.
//!
//! `cargo bench --bench bench_parallel_round`
//! (set `FEDAE_BENCH_MAX_COLLABS=1024` for the largest tier; default 256
//! keeps a full run under a couple of minutes on a laptop.)

use fedae::config::{CompressionConfig, EngineConfig, ExperimentConfig};
use fedae::coordinator::{FlDriver, RoundOutcome};
use fedae::metrics::print_table;
use fedae::runtime::Runtime;
use fedae::util::Stopwatch;

fn cfg_for(collabs: usize, engine: EngineConfig) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_parallel_round_{collabs}");
    cfg.model = "mnist".into();
    // Identity compression: no pre-pass, so setup stays cheap even at
    // 1024 collaborators and the timing isolates the round path the
    // engine parallelizes (train -> encode -> send -> aggregate).
    cfg.compression = CompressionConfig::Identity;
    cfg.fl.collaborators = collabs;
    cfg.fl.rounds = 8; // driver cap; we time fewer below
    cfg.fl.local_epochs = 1;
    cfg.data.per_collab = 64;
    cfg.data.test_size = 128;
    cfg.seed = 17;
    cfg.engine = engine;
    cfg
}

fn timed_rounds(
    rt: &Runtime,
    collabs: usize,
    engine: EngineConfig,
    rounds: usize,
) -> fedae::error::Result<(f64, Vec<RoundOutcome>, Vec<f32>)> {
    let mut driver = FlDriver::builder(rt, cfg_for(collabs, engine)).build()?;
    let sw = Stopwatch::start();
    let mut outcomes = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        outcomes.push(driver.run_round()?);
    }
    let per_round_ms = sw.elapsed_ms() / rounds as f64;
    Ok((per_round_ms, outcomes, driver.global_params().to_vec()))
}

fn main() -> fedae::error::Result<()> {
    let rt = Runtime::from_dir("artifacts")?;
    let workers = fedae::coordinator::ParallelRoundEngine::new(0).workers();
    let max_collabs: usize = std::env::var("FEDAE_BENCH_MAX_COLLABS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    println!("== parallel round engine, synth-mnist, {workers} workers ==");

    let mut rows = Vec::new();
    for collabs in [64, 256, 1024] {
        if collabs > max_collabs {
            println!("(skipping {collabs} collaborators; raise FEDAE_BENCH_MAX_COLLABS)");
            continue;
        }
        let rounds = if collabs >= 1024 { 2 } else { 3 };
        let sequential = EngineConfig {
            parallelism: 1,
            shard_size: 0,
            ..EngineConfig::default()
        };
        let parallel = EngineConfig {
            parallelism: 0,
            shard_size: 0,
            ..EngineConfig::default()
        };
        let parallel_sharded = EngineConfig {
            parallelism: 0,
            shard_size: 4096,
            ..EngineConfig::default()
        };
        let (seq_ms, seq_out, seq_global) = timed_rounds(&rt, collabs, sequential, rounds)?;
        let (par_ms, par_out, par_global) = timed_rounds(&rt, collabs, parallel, rounds)?;
        let (shard_ms, shard_out, shard_global) =
            timed_rounds(&rt, collabs, parallel_sharded, rounds)?;

        // The whole point: parallel and sharded execution change nothing
        // but wall-clock and memory.
        assert_eq!(seq_out, par_out, "parallel outcomes diverged at {collabs}");
        assert_eq!(seq_global, par_global, "parallel params diverged at {collabs}");
        assert_eq!(seq_out, shard_out, "sharded outcomes diverged at {collabs}");
        assert_eq!(seq_global, shard_global, "sharded params diverged at {collabs}");

        let speedup = seq_ms / par_ms;
        rows.push(vec![
            collabs.to_string(),
            format!("{seq_ms:.0}"),
            format!("{par_ms:.0}"),
            format!("{shard_ms:.0}"),
            format!("{speedup:.2}x"),
        ]);
    }
    println!(
        "{}",
        print_table(
            &[
                "collaborators",
                "sequential ms/round",
                "parallel ms/round",
                "parallel+sharded ms/round",
                "speedup"
            ],
            &rows
        )
    );
    println!("(outcomes verified bitwise-identical across all three engines)");
    Ok(())
}
