//! Bench E10 support — compress/decompress latency and wire size of every
//! baseline compressor across update dimensionalities (the paper's §2
//! related-work set), no PJRT needed.
//!
//! `cargo bench --bench bench_baselines`

use fedae::compression::{self};
use fedae::config::CompressionConfig;
use fedae::metrics::print_table;
use fedae::util::bench_timings;
use fedae::util::rng::Rng;

fn main() -> fedae::error::Result<()> {
    println!("== baseline compressor micro-benchmarks ==");
    let mut rng = Rng::new(7);
    for &n in &[15_910usize, 51_082, 550_570] {
        let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let schemes = [
            ("identity", CompressionConfig::Identity),
            ("topk 1%", CompressionConfig::TopK { fraction: 0.01 }),
            (
                "quant 8b",
                CompressionConfig::Quantize { bits: 8, stochastic: false },
            ),
            (
                "quant 4b stoch",
                CompressionConfig::Quantize { bits: 4, stochastic: true },
            ),
            ("subsample 1%", CompressionConfig::Subsample { fraction: 0.01 }),
            (
                "sketch 5x1024",
                CompressionConfig::Sketch { rows: 5, cols: 1024, topk: 512 },
            ),
        ];
        let mut rows = Vec::new();
        for (label, cfg) in schemes {
            let mut c = compression::from_config(&cfg, n, 42)?;
            let mut d = compression::from_config(&cfg, n, 42)?;
            let update = c.compress(0, &w)?;
            let wire = update.wire_bytes();
            let (cm, _, _) = bench_timings(2, 10, || {
                let _ = c.compress(1, &w).unwrap();
            });
            let (dm, _, _) = bench_timings(2, 10, || {
                let _ = d.decompress(&update).unwrap();
            });
            rows.push(vec![
                label.to_string(),
                format!("{:.1}x", (n * 4) as f64 / wire as f64),
                format!("{wire}"),
                format!("{cm:.2}"),
                format!("{dm:.2}"),
            ]);
        }
        println!("\n-- n = {n} params --");
        println!(
            "{}",
            print_table(
                &["scheme", "wire ratio", "wire bytes", "compress ms", "decompress ms"],
                &rows
            )
        );
    }
    println!(
        "\n(AE numbers live in bench_compression — they need the PJRT runtime. \
         At n=550,570 the paper's 1720x AE dwarfs every baseline's ratio.)"
    );
    Ok(())
}
