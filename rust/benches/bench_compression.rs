//! Bench E9 — compression-ratio table (§1/§5 claims: "500x to 1720x and
//! beyond", "nearly as high as 2000x") plus encode/decode latency of the
//! paper's AE scheme at every exported configuration.
//!
//! `cargo bench --bench bench_compression`

use fedae::compression::{ae::AeCompressor, UpdateCompressor};
use fedae::metrics::print_table;
use fedae::runtime::{AePipeline, Runtime};
use fedae::util::bench_timings;

fn main() -> fedae::error::Result<()> {
    // Runs on the native backend from a clean checkout; compiled XLA
    // artifacts are used automatically when present (--features xla).
    let rt = Runtime::from_dir("artifacts")?;
    println!("== E9: compression ratios + AE encode/decode latency ==");

    let mut rows = Vec::new();
    for (tag, model_init) in [
        ("mnist", "mnist_params"),
        ("cifar", "cifar_params"),
        ("mnist_deep", "mnist_params"),
    ] {
        let pipeline = AePipeline::new(&rt, tag)?;
        let ae_params = rt.load_init(&format!("ae_{tag}_init"))?;
        let w = rt.load_init(model_init)?;
        let mut comp = AeCompressor::full(&pipeline, &ae_params)?;

        // Measured wire ratio.
        let update = comp.compress(0, &w)?;
        let wire_ratio = (w.len() * 4) as f64 / update.wire_bytes() as f64;

        let (enc_mean, enc_p50, _) = bench_timings(3, 20, || {
            let _ = comp.compress(0, &w).unwrap();
        });
        let z = match &update {
            fedae::compression::CompressedUpdate::Latent { z, .. } => z.clone(),
            _ => unreachable!(),
        };
        let dec_update = fedae::compression::CompressedUpdate::Latent {
            z,
            n: w.len() as u32,
        };
        let (dec_mean, dec_p50, _) = bench_timings(3, 20, || {
            let _ = comp.decompress(&dec_update).unwrap();
        });

        rows.push(vec![
            format!("ae({tag})"),
            pipeline.n_params.to_string(),
            format!("{}", pipeline.latent),
            format!("{:.1}x", pipeline.input_dim as f64 / pipeline.latent as f64),
            format!("{wire_ratio:.1}x"),
            format!("{enc_mean:.2} ({enc_p50:.2})"),
            format!("{dec_mean:.2} ({dec_p50:.2})"),
        ]);
    }
    println!(
        "{}",
        print_table(
            &[
                "scheme",
                "ae_params",
                "latent",
                "nominal",
                "measured wire",
                "encode ms (p50)",
                "decode ms (p50)",
            ],
            &rows
        )
    );
    println!(
        "paper claims: ~500x (MNIST, latent 32), ~1720x (CIFAR), 'nearly 2000x' \
         with smaller latents — mnist_deep shows the ~1000x point."
    );
    Ok(())
}
