//! Bench — compute-kernel tiers: naive reference loops vs the tiled GEMM
//! layer (ISSUE 5: >= 3x on the default AE train-step shape) vs the
//! AVX2+FMA `simd` microkernels (ISSUE 9: >= tiled GFLOP/s where the CPU
//! supports it; bitwise tiled fallback elsewhere), identical math within
//! float-rounding tolerance.
//!
//! Three tiers:
//! * raw GEMM at the paper-relevant dense shapes (GFLOP/s, speedup),
//! * `ae_train_step` per AE geometry (the pre-pass + per-round hot path),
//! * `classifier_train_step` for the MNIST MLP and the CIFAR-shaped CNN
//!   (im2col + GEMM vs the naive per-pixel conv loops).
//!
//! Besides the tables, the run writes machine-readable results to
//! `BENCH_kernels.json` in the working directory.
//!
//! `cargo bench --bench bench_kernels`
//! (set `FEDAE_BENCH_MAX_COLLABS=1024` to include the largest tier — the
//! 4.1M-param deep-funnel AE — mirroring the other benches' env
//! convention; the default keeps a full run in seconds.)

use fedae::backend::kernels::{self, Epilogue, PackBufs};
use fedae::backend::Kernel;
use fedae::metrics::print_table;
use fedae::runtime::{AdamState, AePipeline, Runtime, TrainStep};
use fedae::util::bench_timings;
use fedae::util::json::Json;

/// Cross-kernel agreement after a multi-step training schedule: nearly
/// all coordinates tight, stragglers (near-zero-gradient sign flips under
/// Adam, ReLU boundary routing) bounded in absolute terms.
fn assert_params_agree(what: &str, naive: &[f32], blocked: &[f32]) {
    let close = naive
        .iter()
        .zip(blocked)
        .filter(|(n, t)| (*n - *t).abs() <= 1e-3 * (1.0 + n.abs()))
        .count();
    let frac = close as f64 / naive.len().max(1) as f64;
    assert!(frac >= 0.99, "{what}: only {frac} of params agree across kernels");
    for (i, (n, t)) in naive.iter().zip(blocked).enumerate() {
        assert!(
            (n - t).abs() <= 0.1,
            "{what}: kernels diverged at param {i}: {n} vs {t}"
        );
    }
}

/// The naive axpy-style matmul the blocked kernels replace (mirrors the
/// reference `dense_forward` loop structure).
fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for (i, crow) in c.chunks_exact_mut(n).enumerate() {
        crow.fill(0.0);
        for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            if av != 0.0 {
                for (cv, &bv) in crow.iter_mut().zip(&b[p * n..(p + 1) * n]) {
                    *cv += av * bv;
                }
            }
        }
    }
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() -> fedae::error::Result<()> {
    let max_collabs: usize = std::env::var("FEDAE_BENCH_MAX_COLLABS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let simd = kernels::simd_available();
    println!(
        "== kernel tiers: naive reference vs tiled vs simd ({}) ==",
        if simd { "avx2+fma detected" } else { "no avx2+fma — simd falls back to tiled" }
    );
    let mut json_gemm = Vec::new();
    let mut json_ae = Vec::new();
    let mut json_clf = Vec::new();

    // --- raw GEMM at the MNIST-AE layer shapes (batch 8) ------------------
    let mut rows = Vec::new();
    for &(m, k, n, what) in &[
        (8usize, 15_910usize, 32usize, "AE encode layer (fwd)"),
        (8, 32, 15_910, "AE decode layer (fwd)"),
        (256, 256, 256, "square reference"),
    ] {
        let a: Vec<f32> = (0..m * k).map(|i| ((i as f32) * 0.13).sin() * 0.1).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i as f32) * 0.29).cos() * 0.1).collect();
        let mut c_naive = vec![0.0f32; m * n];
        let mut c_tiled = vec![0.0f32; m * n];
        let mut c_simd = vec![0.0f32; m * n];
        let mut packs = PackBufs::default();
        let (naive_ms, _, _) = bench_timings(2, 9, || {
            naive_gemm(m, k, n, &a, &b, &mut c_naive);
        });
        packs.exec = kernels::Exec::for_kernel(Kernel::Tiled, 1);
        let (tiled_ms, _, _) = bench_timings(2, 9, || {
            kernels::gemm_nn(&mut packs, m, k, n, &a, &b, &mut c_tiled, Epilogue::Store);
        });
        packs.exec = kernels::Exec::for_kernel(Kernel::Simd, 1);
        let (simd_ms, _, _) = bench_timings(2, 9, || {
            kernels::gemm_nn(&mut packs, m, k, n, &a, &b, &mut c_simd, Epilogue::Store);
        });
        for (label, c) in [("tiled", &c_tiled), ("simd", &c_simd)] {
            for (i, (t, nv)) in c.iter().zip(&c_naive).enumerate() {
                assert!(
                    (t - nv).abs() <= 1e-3 * (1.0 + nv.abs()),
                    "{what}: {label} diverged from naive at {i}: {t} vs {nv}"
                );
            }
        }
        let gflop = 2.0 * (m * k * n) as f64 / 1e9;
        let tiled_gflops = gflop / (tiled_ms / 1e3);
        let simd_gflops = gflop / (simd_ms / 1e3);
        rows.push(vec![
            what.to_string(),
            format!("{m}x{k}x{n}"),
            format!("{naive_ms:.3}"),
            format!("{tiled_ms:.3}"),
            format!("{simd_ms:.3}"),
            format!("{tiled_gflops:.2}"),
            format!("{simd_gflops:.2}"),
            format!("{:.2}x", naive_ms / simd_ms),
        ]);
        json_gemm.push(obj(vec![
            ("what", Json::Str(what.to_string())),
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("naive_ms", Json::Num(naive_ms)),
            ("tiled_ms", Json::Num(tiled_ms)),
            ("simd_ms", Json::Num(simd_ms)),
            ("tiled_gflops", Json::Num(tiled_gflops)),
            ("simd_gflops", Json::Num(simd_gflops)),
            ("speedup_simd_vs_naive", Json::Num(naive_ms / simd_ms)),
            ("speedup_simd_vs_tiled", Json::Num(tiled_ms / simd_ms)),
        ]));
    }
    println!(
        "{}",
        print_table(
            &[
                "gemm",
                "m x k x n",
                "naive ms",
                "tiled ms",
                "simd ms",
                "tiled GFLOP/s",
                "simd GFLOP/s",
                "speedup"
            ],
            &rows
        )
    );

    // --- AE train step (the pre-pass / per-round hot path) ----------------
    let naive_rt = Runtime::builder().kernel(Kernel::Naive).build()?;
    let tiled_rt = Runtime::builder().kernel(Kernel::Tiled).build()?;
    let simd_rt = Runtime::builder().kernel(Kernel::Simd).build()?;
    let mut rows = Vec::new();
    for tag in ["toy", "mnist", "cifar", "mnist_deep"] {
        if tag == "mnist_deep" && max_collabs < 1024 {
            println!("(skipping mnist_deep AE; set FEDAE_BENCH_MAX_COLLABS=1024)");
            continue;
        }
        let iters = if tag == "toy" { 40 } else { 10 };
        let mut step_ms = Vec::new();
        let mut final_params = Vec::new();
        for rt in [&naive_rt, &tiled_rt, &simd_rt] {
            let pipe = AePipeline::new(rt, tag)?;
            let mut ae = rt.load_init(&format!("ae_{tag}_init"))?;
            let mut adam = AdamState::zeros(ae.len());
            let batch: Vec<f32> = (0..pipe.train_batch * pipe.input_dim)
                .map(|i| ((i as f32 * 0.37).sin()) * 0.05)
                .collect();
            let (mean, _, _) = bench_timings(2, iters, || {
                let _ = pipe.train_step(&mut ae, &mut adam, &batch).unwrap();
            });
            step_ms.push(mean);
            final_params.push(ae);
        }
        // Same math: after the identical step schedule every kernel holds
        // near-identical parameters (sign-flip coordinates of near-zero
        // gradients are bounded by the Adam step size; see
        // rust/tests/kernels.rs for the tight assertions).
        assert_params_agree(tag, &final_params[0], &final_params[1]);
        assert_params_agree(tag, &final_params[0], &final_params[2]);
        let pipe = AePipeline::new(&tiled_rt, tag)?;
        // fwd + two backward GEMMs per layer ~ 6 flops per param per sample.
        let gflop = 6.0 * (pipe.n_params * pipe.train_batch) as f64 / 1e9;
        let simd_gflops = gflop / (step_ms[2] / 1e3);
        rows.push(vec![
            tag.to_string(),
            pipe.n_params.to_string(),
            format!("{:.2}", step_ms[0]),
            format!("{:.2}", step_ms[1]),
            format!("{:.2}", step_ms[2]),
            format!("{simd_gflops:.2}"),
            format!("{:.2}x", step_ms[0] / step_ms[2]),
        ]);
        json_ae.push(obj(vec![
            ("tag", Json::Str(tag.to_string())),
            ("params", Json::Num(pipe.n_params as f64)),
            ("naive_ms", Json::Num(step_ms[0])),
            ("tiled_ms", Json::Num(step_ms[1])),
            ("simd_ms", Json::Num(step_ms[2])),
            ("simd_gflops", Json::Num(simd_gflops)),
            ("speedup_simd_vs_naive", Json::Num(step_ms[0] / step_ms[2])),
            ("speedup_simd_vs_tiled", Json::Num(step_ms[1] / step_ms[2])),
        ]));
    }
    println!(
        "{}",
        print_table(
            &["ae_train_step", "params", "naive ms", "tiled ms", "simd ms", "~GFLOP/s", "speedup"],
            &rows
        )
    );

    // --- classifier train step (MLP + im2col CNN) -------------------------
    let mut rows = Vec::new();
    for family in ["mnist", "cifar"] {
        let iters = if family == "cifar" { 8 } else { 20 };
        let mut step_ms = Vec::new();
        let mut final_params = Vec::new();
        for rt in [&naive_rt, &tiled_rt, &simd_rt] {
            let ts = TrainStep::new(rt, family)?;
            let mut params = rt.load_init(&format!("{family}_params"))?;
            let x: Vec<f32> = (0..ts.batch * ts.input_dim)
                .map(|i| ((i as f32 * 0.11).sin() + 1.0) * 0.5)
                .collect();
            let mut y = vec![0.0f32; ts.batch * ts.classes];
            for b in 0..ts.batch {
                y[b * ts.classes + b % ts.classes] = 1.0;
            }
            let (mean, _, _) = bench_timings(2, iters, || {
                let (np, _) = ts.step(&params, &x, &y, 0.05).unwrap();
                params = np;
            });
            step_ms.push(mean);
            final_params.push(params);
        }
        assert_params_agree(family, &final_params[0], &final_params[1]);
        assert_params_agree(family, &final_params[0], &final_params[2]);
        rows.push(vec![
            family.to_string(),
            format!("{:.2}", step_ms[0]),
            format!("{:.2}", step_ms[1]),
            format!("{:.2}", step_ms[2]),
            format!("{:.2}x", step_ms[0] / step_ms[2]),
        ]);
        json_clf.push(obj(vec![
            ("family", Json::Str(family.to_string())),
            ("naive_ms", Json::Num(step_ms[0])),
            ("tiled_ms", Json::Num(step_ms[1])),
            ("simd_ms", Json::Num(step_ms[2])),
            ("speedup_simd_vs_naive", Json::Num(step_ms[0] / step_ms[2])),
        ]));
    }
    println!(
        "{}",
        print_table(
            &["classifier_train_step", "naive ms", "tiled ms", "simd ms", "speedup"],
            &rows
        )
    );
    println!("(tiled and simd results verified against naive within rounding tolerance)");

    let doc = obj(vec![
        ("bench", Json::Str("kernels".to_string())),
        ("simd_available", Json::Bool(simd)),
        ("gemm", Json::Arr(json_gemm)),
        ("ae_train_step", Json::Arr(json_ae)),
        ("classifier_train_step", Json::Arr(json_clf)),
    ]);
    std::fs::write("BENCH_kernels.json", doc.to_string_pretty())?;
    println!("machine-readable results written to BENCH_kernels.json");
    Ok(())
}
