//! Bench E7/E8 — regenerate the Fig 10/11 series (analytic, paper's own
//! constants) and time the sweep machinery at large-scale-FL grid sizes.
//!
//! `cargo bench --bench bench_savings`

use fedae::metrics::print_table;
use fedae::savings::{PAPER_CIFAR, REPO_MNIST};
use fedae::util::bench_timings;

fn main() -> fedae::error::Result<()> {
    println!("== E7 (Fig 10): SR vs collaborators, single decoder ==");
    let collab_grid: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 40, 64, 128, 256, 512, 1000, 2000, 5000];
    let mut rows = Vec::new();
    for rounds in [8usize, 41, 100] {
        let sweep = PAPER_CIFAR.sweep_collabs(rounds, &collab_grid)?;
        for (c, sr) in &sweep {
            if [1usize, 40, 1000, 5000].contains(c) {
                rows.push(vec![
                    rounds.to_string(),
                    c.to_string(),
                    format!("{sr:.2}"),
                    if *sr >= 1.0 { "saves".into() } else { "costs".into() },
                ]);
            }
        }
    }
    println!("{}", print_table(&["rounds", "collabs", "SR", "verdict"], &rows));
    println!(
        "break-even: R=8 -> {} collabs (paper: 40); SR(1000)@R=41 = {:.0}x (paper: ~120x)",
        PAPER_CIFAR.breakeven_collabs_single_decoder(8)?,
        PAPER_CIFAR.savings_ratio_single_decoder(41, 1000)?
    );

    println!("\n== E8 (Fig 11): SR vs rounds, per-collaborator decoders ==");
    let round_grid: Vec<usize> = vec![10, 100, 320, 321, 640, 1000, 10_000];
    let rows: Vec<Vec<String>> = PAPER_CIFAR
        .sweep_rounds(7, &round_grid)?
        .into_iter()
        .map(|(r, sr)| {
            vec![
                r.to_string(),
                format!("{sr:.3}"),
                if sr >= 1.0 { "saves".into() } else { "costs".into() },
            ]
        })
        .collect();
    println!("{}", print_table(&["rounds", "SR", "verdict"], &rows));
    println!(
        "break-even: {} rounds (paper: 320)",
        PAPER_CIFAR.breakeven_rounds_per_collab_decoders()?
    );

    // Perf: a 1M-point sweep must stay trivially cheap (it backs the CLI
    // and any dashboarding a deployment would do).
    let big_grid: Vec<usize> = (1..=1_000_000).step_by(100).collect();
    let (mean, p50, p95) = bench_timings(1, 10, || {
        let _ = PAPER_CIFAR.sweep_collabs(100, &big_grid).unwrap();
    });
    println!(
        "\nsweep perf: {} points -> mean {mean:.2} ms, p50 {p50:.2} ms, p95 {p95:.2} ms",
        big_grid.len()
    );

    println!(
        "\nrepo-scale model: ratio {:.1}x, case-b break-even {} rounds",
        REPO_MNIST.compression_ratio(),
        REPO_MNIST.breakeven_rounds_per_collab_decoders()?
    );
    Ok(())
}
