//! Bench — wire-format and transport cost (ISSUE 8): frame encode /
//! decode throughput (frames/s and MB/s) at the mnist (15,910-param)
//! and cifar (51,082-param) model sizes, plus one simulated round trip
//! (GlobalModel down, EncodedUpdate up) over the in-proc channel versus
//! a real loopback-TCP socket.
//!
//! Carries the byte-count parity assert: `Transport::send` must report
//! exactly `Message::wire_bytes()` on both transports — the invariant
//! that makes the protocol coordinator's traffic ledger bitwise-equal
//! to the simulator's.
//!
//! `cargo bench --bench bench_transport`

use std::net::TcpListener;
use std::thread;

use fedae::metrics::print_table;
use fedae::transport::{InProcChannel, Message, TcpTransport, Transport};
use fedae::util::rng::Rng;
use fedae::util::Stopwatch;

/// (model tag, parameter count) tiers.
const TIERS: [(&str, usize); 2] = [("mnist", 15_910), ("cifar", 51_082)];

/// Encode/decode repetitions per tier.
const REPS: usize = 200;
/// Round trips per transport per tier.
const TRIPS: usize = 50;

fn global_model(n: usize) -> Message {
    let mut rng = Rng::new(0x7ea1);
    Message::GlobalModel {
        round: 3,
        params: (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
    }
}

/// A latent-sized uplink frame (the AE wire format: tiny next to the
/// model) plus an identity-sized one for the uncompressed bound.
fn encoded_update(payload_bytes: usize) -> Message {
    let mut rng = Rng::new(0xf10a);
    // Scheme byte 0 = Raw; the payload body is opaque to the transport.
    let mut payload = vec![0u8; payload_bytes];
    for b in payload.iter_mut().skip(1) {
        *b = rng.below(256) as u8;
    }
    Message::encoded_update(3, 1, 512, payload)
}

fn encode_decode_row(tag: &str, msg: &Message) -> Vec<String> {
    let frame = msg.to_frame();
    let mb = frame.len() as f64 / 1e6;

    let sw = Stopwatch::start();
    for _ in 0..REPS {
        std::hint::black_box(msg.to_frame());
    }
    let enc_s = sw.elapsed_secs();

    let sw = Stopwatch::start();
    for _ in 0..REPS {
        std::hint::black_box(Message::from_frame(&frame).expect("bench frame parses"));
    }
    let dec_s = sw.elapsed_secs();

    vec![
        tag.to_string(),
        format!("{}", frame.len()),
        format!("{:.0}", REPS as f64 / enc_s),
        format!("{:.1}", REPS as f64 * mb / enc_s),
        format!("{:.0}", REPS as f64 / dec_s),
        format!("{:.1}", REPS as f64 * mb / dec_s),
    ]
}

/// One federated exchange: coordinator sends the global model, the
/// worker answers with an encoded update. Returns ms per round trip.
fn round_trip_ms(
    coord: &mut dyn Transport,
    worker_done: thread::JoinHandle<()>,
    down: &Message,
) -> f64 {
    let sw = Stopwatch::start();
    for _ in 0..TRIPS {
        coord.send(down).expect("send global");
        let up = coord.recv().expect("recv update");
        assert!(matches!(up, Message::EncodedUpdate { .. }));
    }
    let ms = sw.elapsed_secs() * 1e3 / TRIPS as f64;
    worker_done.join().expect("worker thread");
    ms
}

/// The worker half of the echo exchange: answer every `GlobalModel`
/// with the prebuilt update, assert reported bytes match `wire_bytes`.
fn echo_worker(mut t: impl Transport + 'static, up: Message) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        for _ in 0..TRIPS {
            let down = t.recv().expect("recv global");
            assert!(matches!(down, Message::GlobalModel { .. }));
            let sent = t.send(&up).expect("send update");
            assert_eq!(sent, up.wire_bytes(), "transport under-reported bytes");
        }
    })
}

fn transport_rows(n_params: usize, tag: &str) -> Vec<Vec<String>> {
    let down = global_model(n_params);
    // AE-latent-sized uplink: 600 latent floats ≈ the paper's z-dim.
    let up = encoded_update(600 * 4 + 9);

    // Byte-count parity: both transports report wire_bytes exactly.
    let (mut a, mut b) = InProcChannel::pair();
    let sent = Transport::send(&mut a, &down).expect("in-proc send");
    assert_eq!(sent, down.wire_bytes());
    let _ = Transport::recv(&mut b).expect("in-proc recv");

    // In-proc round trip.
    let (mut coord, worker) = InProcChannel::pair();
    let h = echo_worker(worker, up.clone());
    let inproc_ms = round_trip_ms(&mut coord, h, &down);

    // Loopback-TCP round trip.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let accept = thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        TcpTransport::new(stream)
    });
    let mut coord = TcpTransport::connect(&addr).expect("connect loopback");
    let worker = accept.join().expect("accept thread");
    let h = echo_worker(worker, up.clone());
    let tcp_ms = round_trip_ms(&mut coord, h, &down);

    vec![vec![
        tag.to_string(),
        format!("{}", down.wire_bytes()),
        format!("{}", up.wire_bytes()),
        format!("{inproc_ms:.3}"),
        format!("{tcp_ms:.3}"),
    ]]
}

fn main() {
    println!("== frame encode/decode, {REPS} reps ==");
    let mut rows = Vec::new();
    for (tag, n) in TIERS {
        rows.push(encode_decode_row(&format!("global_{tag}"), &global_model(n)));
        rows.push(encode_decode_row(
            &format!("update_raw_{tag}"),
            &encoded_update(n * 4 + 1),
        ));
    }
    rows.push(encode_decode_row("update_latent", &encoded_update(600 * 4 + 9)));
    println!(
        "{}",
        print_table(
            &["frame", "bytes", "enc fps", "enc MB/s", "dec fps", "dec MB/s"],
            &rows
        )
    );

    println!("== one round trip (GlobalModel down, latent update up), {TRIPS} trips ==");
    let mut rows = Vec::new();
    for (tag, n) in TIERS {
        rows.extend(transport_rows(n, tag));
    }
    println!(
        "{}",
        print_table(
            &["model", "down B", "up B", "in-proc ms", "tcp ms"],
            &rows
        )
    );
    println!("(Transport::send == wire_bytes asserted on both transports)");
}
