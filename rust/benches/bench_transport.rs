//! Bench — wire-format and transport cost (ISSUE 8): frame encode /
//! decode throughput (frames/s and MB/s) at the mnist (15,910-param)
//! and cifar (51,082-param) model sizes, plus one simulated round trip
//! (GlobalModel down, EncodedUpdate up) over the in-proc channel versus
//! a real loopback-TCP socket, and the reconnect path (ISSUE 10): a
//! dead worker's TCP redial + `Rejoin` up + full-params `CatchUp` down,
//! per model tier.
//!
//! Carries the byte-count parity assert: `Transport::send` must report
//! exactly `Message::wire_bytes()` on both transports — the invariant
//! that makes the protocol coordinator's traffic ledger bitwise-equal
//! to the simulator's.
//!
//! Besides the tables, the run writes machine-readable results to
//! `BENCH_transport.json` in the working directory.
//!
//! `cargo bench --bench bench_transport`

use std::net::TcpListener;
use std::thread;

use fedae::metrics::print_table;
use fedae::transport::{InProcChannel, Message, TcpTransport, Transport};
use fedae::util::json::Json;
use fedae::util::rng::Rng;
use fedae::util::Stopwatch;

/// (model tag, parameter count) tiers.
const TIERS: [(&str, usize); 2] = [("mnist", 15_910), ("cifar", 51_082)];

/// Encode/decode repetitions per tier.
const REPS: usize = 200;
/// Round trips per transport per tier.
const TRIPS: usize = 50;
/// Reconnect → catch-up cycles per tier.
const RECONNECTS: usize = 30;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn global_model(n: usize) -> Message {
    let mut rng = Rng::new(0x7ea1);
    Message::GlobalModel {
        round: 3,
        params: (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
    }
}

/// A latent-sized uplink frame (the AE wire format: tiny next to the
/// model) plus an identity-sized one for the uncompressed bound.
fn encoded_update(payload_bytes: usize) -> Message {
    let mut rng = Rng::new(0xf10a);
    // Scheme byte 0 = Raw; the payload body is opaque to the transport.
    let mut payload = vec![0u8; payload_bytes];
    for b in payload.iter_mut().skip(1) {
        *b = rng.below(256) as u8;
    }
    Message::encoded_update(3, 1, 512, payload)
}

fn encode_decode_row(tag: &str, msg: &Message) -> (Vec<String>, Json) {
    let frame = msg.to_frame();
    let mb = frame.len() as f64 / 1e6;

    let sw = Stopwatch::start();
    for _ in 0..REPS {
        std::hint::black_box(msg.to_frame());
    }
    let enc_s = sw.elapsed_secs();

    let sw = Stopwatch::start();
    for _ in 0..REPS {
        std::hint::black_box(Message::from_frame(&frame).expect("bench frame parses"));
    }
    let dec_s = sw.elapsed_secs();

    let row = vec![
        tag.to_string(),
        format!("{}", frame.len()),
        format!("{:.0}", REPS as f64 / enc_s),
        format!("{:.1}", REPS as f64 * mb / enc_s),
        format!("{:.0}", REPS as f64 / dec_s),
        format!("{:.1}", REPS as f64 * mb / dec_s),
    ];
    let json = obj(vec![
        ("frame", Json::Str(tag.to_string())),
        ("bytes", Json::Num(frame.len() as f64)),
        ("enc_fps", Json::Num(REPS as f64 / enc_s)),
        ("enc_mb_s", Json::Num(REPS as f64 * mb / enc_s)),
        ("dec_fps", Json::Num(REPS as f64 / dec_s)),
        ("dec_mb_s", Json::Num(REPS as f64 * mb / dec_s)),
    ]);
    (row, json)
}

/// One federated exchange: coordinator sends the global model, the
/// worker answers with an encoded update. Returns ms per round trip.
fn round_trip_ms(
    coord: &mut dyn Transport,
    worker_done: thread::JoinHandle<()>,
    down: &Message,
) -> f64 {
    let sw = Stopwatch::start();
    for _ in 0..TRIPS {
        coord.send(down).expect("send global");
        let up = coord.recv().expect("recv update");
        assert!(matches!(up, Message::EncodedUpdate { .. }));
    }
    let ms = sw.elapsed_secs() * 1e3 / TRIPS as f64;
    worker_done.join().expect("worker thread");
    ms
}

/// The worker half of the echo exchange: answer every `GlobalModel`
/// with the prebuilt update, assert reported bytes match `wire_bytes`.
fn echo_worker(mut t: impl Transport + 'static, up: Message) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        for _ in 0..TRIPS {
            let down = t.recv().expect("recv global");
            assert!(matches!(down, Message::GlobalModel { .. }));
            let sent = t.send(&up).expect("send update");
            assert_eq!(sent, up.wire_bytes(), "transport under-reported bytes");
        }
    })
}

fn transport_row(n_params: usize, tag: &str) -> (Vec<String>, Json) {
    let down = global_model(n_params);
    // AE-latent-sized uplink: 600 latent floats ≈ the paper's z-dim.
    let up = encoded_update(600 * 4 + 9);

    // Byte-count parity: both transports report wire_bytes exactly.
    let (mut a, mut b) = InProcChannel::pair();
    let sent = Transport::send(&mut a, &down).expect("in-proc send");
    assert_eq!(sent, down.wire_bytes());
    let _ = Transport::recv(&mut b).expect("in-proc recv");

    // In-proc round trip.
    let (mut coord, worker) = InProcChannel::pair();
    let h = echo_worker(worker, up.clone());
    let inproc_ms = round_trip_ms(&mut coord, h, &down);

    // Loopback-TCP round trip.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let accept = thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        TcpTransport::new(stream)
    });
    let mut coord = TcpTransport::connect(&addr).expect("connect loopback");
    let worker = accept.join().expect("accept thread");
    let h = echo_worker(worker, up.clone());
    let tcp_ms = round_trip_ms(&mut coord, h, &down);

    let row = vec![
        tag.to_string(),
        format!("{}", down.wire_bytes()),
        format!("{}", up.wire_bytes()),
        format!("{inproc_ms:.3}"),
        format!("{tcp_ms:.3}"),
    ];
    let json = obj(vec![
        ("model", Json::Str(tag.to_string())),
        ("down_bytes", Json::Num(down.wire_bytes() as f64)),
        ("up_bytes", Json::Num(up.wire_bytes() as f64)),
        ("inproc_ms", Json::Num(inproc_ms)),
        ("tcp_ms", Json::Num(tcp_ms)),
    ]);
    (row, json)
}

/// Reconnect → catch-up latency: a dead worker re-enters the federation
/// with a fresh TCP dial, a `Rejoin` frame up, and a full-params
/// `CatchUp` down — the recovery path `ReconnectingTransport` drives
/// after a lost connection.
fn reconnect_row(n_params: usize, tag: &str) -> (Vec<String>, Json) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let params = match global_model(n_params) {
        Message::GlobalModel { params, .. } => params,
        _ => unreachable!("global_model builds a GlobalModel"),
    };
    let catch_up = Message::CatchUp {
        round: 3,
        decoder_needed: false,
        params,
    };
    let catch_up_bytes = catch_up.wire_bytes();
    let coordinator = thread::spawn(move || {
        for _ in 0..RECONNECTS {
            let (stream, _) = listener.accept().expect("accept redial");
            let mut t = TcpTransport::new(stream);
            let rejoin = t.recv().expect("recv rejoin");
            assert!(matches!(rejoin, Message::Rejoin { .. }));
            t.send(&catch_up).expect("send catch-up");
        }
    });

    let sw = Stopwatch::start();
    for _ in 0..RECONNECTS {
        let mut t = TcpTransport::connect(&addr).expect("redial");
        t.send(&Message::Rejoin {
            collab_id: 1,
            last_round: 2,
        })
        .expect("send rejoin");
        let got = t.recv().expect("recv catch-up");
        assert!(matches!(got, Message::CatchUp { .. }));
    }
    let ms = sw.elapsed_secs() * 1e3 / RECONNECTS as f64;
    coordinator.join().expect("coordinator thread");

    let row = vec![
        tag.to_string(),
        format!("{catch_up_bytes}"),
        format!("{ms:.3}"),
    ];
    let json = obj(vec![
        ("model", Json::Str(tag.to_string())),
        ("catch_up_bytes", Json::Num(catch_up_bytes as f64)),
        ("reconnect_catch_up_ms", Json::Num(ms)),
    ]);
    (row, json)
}

fn main() {
    let mut json_codec = Vec::new();
    let mut json_trip = Vec::new();
    let mut json_reconnect = Vec::new();

    println!("== frame encode/decode, {REPS} reps ==");
    let mut rows = Vec::new();
    for (tag, n) in TIERS {
        for (label, msg) in [
            (format!("global_{tag}"), global_model(n)),
            (format!("update_raw_{tag}"), encoded_update(n * 4 + 1)),
        ] {
            let (row, json) = encode_decode_row(&label, &msg);
            rows.push(row);
            json_codec.push(json);
        }
    }
    let (row, json) = encode_decode_row("update_latent", &encoded_update(600 * 4 + 9));
    rows.push(row);
    json_codec.push(json);
    println!(
        "{}",
        print_table(
            &["frame", "bytes", "enc fps", "enc MB/s", "dec fps", "dec MB/s"],
            &rows
        )
    );

    println!("== one round trip (GlobalModel down, latent update up), {TRIPS} trips ==");
    let mut rows = Vec::new();
    for (tag, n) in TIERS {
        let (row, json) = transport_row(n, tag);
        rows.push(row);
        json_trip.push(json);
    }
    println!(
        "{}",
        print_table(
            &["model", "down B", "up B", "in-proc ms", "tcp ms"],
            &rows
        )
    );
    println!("(Transport::send == wire_bytes asserted on both transports)");

    println!("== reconnect -> catch-up (dial + Rejoin up + CatchUp down), {RECONNECTS} cycles ==");
    let mut rows = Vec::new();
    for (tag, n) in TIERS {
        let (row, json) = reconnect_row(n, tag);
        rows.push(row);
        json_reconnect.push(json);
    }
    println!(
        "{}",
        print_table(&["model", "catch-up B", "reconnect ms"], &rows)
    );

    let doc = obj(vec![
        ("encode_decode", Json::Arr(json_codec)),
        ("round_trip", Json::Arr(json_trip)),
        ("reconnect", Json::Arr(json_reconnect)),
    ]);
    std::fs::write("BENCH_transport.json", doc.to_string_pretty())
        .expect("write BENCH_transport.json");
    println!("machine-readable results written to BENCH_transport.json");
}
