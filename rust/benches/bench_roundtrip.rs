//! Bench — PJRT executable latency for every request-path artifact:
//! classifier train/eval steps, AE encode/decode/roundtrip. This is the
//! L3 hot path's compute budget; see EXPERIMENTS.md §Perf.
//!
//! `cargo bench --bench bench_roundtrip`

use fedae::metrics::print_table;
use fedae::runtime::{AePipeline, EvalStep, Runtime, TrainStep};
use fedae::util::bench_timings;

fn main() -> fedae::error::Result<()> {
    // Runs on the native backend from a clean checkout; compiled XLA
    // artifacts are used automatically when present (--features xla).
    let rt = Runtime::from_dir("artifacts")?;
    println!("== PJRT artifact latency (platform: {}) ==", rt.platform_name());
    let mut rows = Vec::new();

    for family in ["mnist", "cifar"] {
        let params = rt.load_init(&format!("{family}_params"))?;
        let ts = TrainStep::new(&rt, family)?;
        let x = vec![0.1f32; ts.batch * ts.input_dim];
        let mut y = vec![0.0f32; ts.batch * ts.classes];
        for b in 0..ts.batch {
            y[b * ts.classes + b % 10] = 1.0;
        }
        let (m, p50, p95) = bench_timings(3, 25, || {
            let _ = ts.step(&params, &x, &y, 0.05).unwrap();
        });
        rows.push(vec![
            format!("{family}_train_step"),
            format!("B={}", ts.batch),
            format!("{m:.2}"),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
        ]);

        let ev = EvalStep::new(&rt, family)?;
        let xe = vec![0.1f32; ev.batch * ev.input_dim];
        let mut ye = vec![0.0f32; ev.batch * ev.classes];
        for b in 0..ev.batch {
            ye[b * ev.classes + b % 10] = 1.0;
        }
        let (m, p50, p95) = bench_timings(3, 25, || {
            let _ = ev.eval(&params, &xe, &ye).unwrap();
        });
        rows.push(vec![
            format!("{family}_eval"),
            format!("B={}", ev.batch),
            format!("{m:.2}"),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
        ]);
    }

    for tag in ["mnist", "cifar", "mnist_deep"] {
        let pipe = AePipeline::new(&rt, tag)?;
        let ae = rt.load_init(&format!("ae_{tag}_init"))?;
        let (enc, dec) = pipe.split(&ae)?;
        let w = vec![0.01f32; pipe.input_dim];
        let (m, p50, p95) = bench_timings(3, 25, || {
            let _ = pipe.encode(&enc, &w).unwrap();
        });
        rows.push(vec![
            format!("encode_{tag}"),
            format!("n={}", pipe.input_dim),
            format!("{m:.2}"),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
        ]);
        let z = pipe.encode(&enc, &w)?;
        let (m, p50, p95) = bench_timings(3, 25, || {
            let _ = pipe.decode(&dec, &z).unwrap();
        });
        rows.push(vec![
            format!("decode_{tag}"),
            format!("z={}", pipe.latent),
            format!("{m:.2}"),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
        ]);
        let (m, p50, p95) = bench_timings(3, 15, || {
            let _ = pipe.roundtrip(&ae, &w).unwrap();
        });
        rows.push(vec![
            format!("ae_roundtrip_{tag}"),
            String::new(),
            format!("{m:.2}"),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
        ]);
    }

    println!(
        "{}",
        print_table(&["artifact", "shape", "mean ms", "p50 ms", "p95 ms"], &rows)
    );
    Ok(())
}
