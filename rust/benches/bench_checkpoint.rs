//! Bench — checkpoint subsystem cost (ISSUE 7): snapshot capture +
//! serialization, snapshot write, driver restore, and per-round
//! checkpointing overhead (event-log append + snapshot cadence), at 256
//! and 1024 registered collaborators.
//!
//! Each tier also carries the acceptance assert: the checkpointed run
//! must produce bitwise the same outcomes as the plain run, and a driver
//! resumed from the last snapshot must finish the experiment with the
//! same final parameters as the uninterrupted one.
//!
//! `cargo bench --bench bench_checkpoint`

use std::fs;
use std::path::{Path, PathBuf};

use fedae::config::{CompressionConfig, ExperimentConfig};
use fedae::coordinator::checkpoint;
use fedae::coordinator::{FlDriver, RoundOutcome};
use fedae::metrics::print_table;
use fedae::runtime::Runtime;
use fedae::util::Stopwatch;

/// Rounds run before the simulated crash; the experiment has two more.
const CUT: usize = 4;
const ACTIVE: usize = 32;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedae_ckpt_bench_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn cfg_for(registered: usize, ckpt_dir: Option<&Path>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_checkpoint_{registered}");
    cfg.model = "mnist".into();
    cfg.compression = CompressionConfig::Identity;
    cfg.fl.collaborators = registered;
    cfg.fl.rounds = CUT + 2;
    cfg.fl.local_epochs = 1;
    cfg.data.per_collab = 32;
    cfg.data.test_size = 64;
    cfg.seed = 53;
    cfg.selection.count = ACTIVE;
    cfg.engine.parallelism = 0;
    if let Some(dir) = ckpt_dir {
        cfg.checkpoint.dir = dir.to_string_lossy().into_owned();
        cfg.checkpoint.every_rounds = 1;
    }
    cfg
}

fn run_rounds(driver: &mut FlDriver<'_>, n: usize) -> fedae::error::Result<Vec<RoundOutcome>> {
    let mut outcomes = Vec::with_capacity(n);
    for _ in 0..n {
        outcomes.push(driver.run_round()?);
    }
    Ok(outcomes)
}

fn run_tier(rt: &Runtime, registered: usize) -> fedae::error::Result<Vec<String>> {
    let rounds = CUT + 2;

    // Plain reference run: no checkpointing.
    let sw = Stopwatch::start();
    let mut plain = FlDriver::builder(rt, cfg_for(registered, None)).build()?;
    let plain_outcomes = run_rounds(&mut plain, rounds)?;
    let plain_ms = sw.elapsed_ms();
    let plain_bits: Vec<u32> = plain.global_params().iter().map(|v| v.to_bits()).collect();
    drop(plain);

    // Checkpointed twin, interrupted after CUT rounds.
    let dir = scratch(&format!("tier_{registered}"));
    let cfg = cfg_for(registered, Some(&dir));
    let sw = Stopwatch::start();
    let mut ck = FlDriver::builder(rt, cfg.clone()).build()?;
    let ck_outcomes = run_rounds(&mut ck, CUT)?;
    let ck_ms_per_round = sw.elapsed_ms() / CUT as f64;
    assert_eq!(
        plain_outcomes[..CUT],
        ck_outcomes[..],
        "{registered}: checkpointing perturbed round outcomes"
    );
    let overhead_ms = ck_ms_per_round - plain_ms / rounds as f64;

    // Snapshot capture + serialization cost (amortized over repeats).
    const REPS: usize = 10;
    let sw = Stopwatch::start();
    let mut snapshot_bytes = 0usize;
    for _ in 0..REPS {
        snapshot_bytes = ck.snapshot()?.to_bytes().len();
    }
    let capture_ms = sw.elapsed_ms() / REPS as f64;
    drop(ck); // simulated crash

    let log_bytes = fs::metadata(checkpoint::events_path(&dir))?.len();

    // Restore cost: rebuild a live driver from the newest snapshot.
    let sw = Stopwatch::start();
    let mut resumed = FlDriver::builder(rt, cfg).resume_from(&dir).build()?;
    let restore_ms = sw.elapsed_ms();
    assert_eq!(resumed.round(), CUT, "{registered}: wrong resume round");

    // Acceptance: the resumed tail matches the uninterrupted run bitwise.
    let tail = run_rounds(&mut resumed, rounds - CUT)?;
    assert_eq!(
        plain_outcomes[CUT..],
        tail[..],
        "{registered}: resumed outcomes diverged"
    );
    let resumed_bits: Vec<u32> = resumed.global_params().iter().map(|v| v.to_bits()).collect();
    assert_eq!(
        plain_bits, resumed_bits,
        "{registered}: resumed final params diverged"
    );
    drop(resumed);
    fs::remove_dir_all(&dir)?;

    Ok(vec![
        registered.to_string(),
        format!("{capture_ms:.2}"),
        format!("{}", snapshot_bytes / 1024),
        format!("{restore_ms:.0}"),
        format!("{}", log_bytes / CUT as u64),
        format!("{overhead_ms:.2}"),
    ])
}

fn main() -> fedae::error::Result<()> {
    let rt = Runtime::from_dir("artifacts")?;
    println!("== checkpoint cost, K={ACTIVE} active, snapshot every round ==");
    let mut rows = Vec::new();
    for registered in [256usize, 1024] {
        rows.push(run_tier(&rt, registered)?);
    }
    println!(
        "{}",
        print_table(
            &[
                "registered",
                "snapshot ms",
                "snapshot KiB",
                "restore ms",
                "log B/round",
                "overhead ms/round",
            ],
            &rows
        )
    );
    println!("(resumed == uninterrupted asserted bitwise at both tiers)");
    Ok(())
}
