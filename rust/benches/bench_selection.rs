//! Bench — million-client federations on the seeded-selection + lazy
//! collaborator pool (ISSUE 6 acceptance: a 1,000,000-registered /
//! 256-active round costs roughly what a 256-collaborator round costs,
//! in both time and resident state).
//!
//! Per registered-population tier this runs the same fixed-seed sampled
//! experiment (K = 256 uniform selection, resident pool capped at 512)
//! and reports per-round wall time, activations and resident clients.
//! Round time and resident state must stay ~flat in N: the asserts fail
//! if the 1M-client tier costs more than 5x the 1k-client tier per round
//! or the pool ever exceeds its bound.
//!
//! `cargo bench --bench bench_selection`
//! (set `FEDAE_BENCH_MAX_CLIENTS=100000` to skip the 1M tier on small
//! machines; default runs all three tiers.)

use fedae::config::{CompressionConfig, ExperimentConfig};
use fedae::coordinator::FlDriver;
use fedae::metrics::print_table;
use fedae::runtime::Runtime;
use fedae::util::Stopwatch;

const ACTIVE: usize = 256;
const MAX_RESIDENT: usize = 512;

fn cfg_for(registered: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_selection_{registered}");
    cfg.model = "mnist".into();
    // Identity compression: no pre-pass, so activation cost is dominated
    // by shard synthesis + collaborator state, the thing the lazy pool
    // must keep O(active).
    cfg.compression = CompressionConfig::Identity;
    cfg.fl.collaborators = registered;
    cfg.fl.rounds = 4; // driver cap; we time fewer below
    cfg.fl.local_epochs = 1;
    cfg.data.per_collab = 32;
    cfg.data.test_size = 64;
    cfg.seed = 53;
    cfg.selection.count = ACTIVE.min(registered);
    cfg.selection.max_resident = MAX_RESIDENT.min(registered);
    cfg.engine.parallelism = 0;
    cfg
}

struct Tier {
    per_round_ms: f64,
    activated: usize,
    resident_peak: usize,
}

fn run_tier(rt: &Runtime, registered: usize, rounds: usize) -> fedae::error::Result<Tier> {
    let mut driver = FlDriver::builder(rt, cfg_for(registered)).build()?;
    let sw = Stopwatch::start();
    let mut activated = 0;
    let mut resident_peak = 0;
    for _ in 0..rounds {
        let out = driver.run_round()?;
        activated += out.selection.newly_activated;
        resident_peak = resident_peak.max(out.selection.resident);
        assert_eq!(out.selection.sampled, ACTIVE.min(registered));
    }
    let per_round_ms = sw.elapsed_ms() / rounds as f64;
    assert!(
        driver.resident_clients() <= MAX_RESIDENT,
        "{registered}: resident pool {} exceeds bound {MAX_RESIDENT}",
        driver.resident_clients()
    );
    Ok(Tier {
        per_round_ms,
        activated,
        resident_peak,
    })
}

fn main() -> fedae::error::Result<()> {
    let rt = Runtime::from_dir("artifacts")?;
    let max_clients: usize = std::env::var("FEDAE_BENCH_MAX_CLIENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    println!("== seeded selection + lazy pool, K={ACTIVE} active, synth-mnist ==");

    let mut rows = Vec::new();
    let mut baseline_ms = None;
    let mut top_tier_ms = None;
    for registered in [1_000usize, 100_000, 1_000_000] {
        if registered > max_clients {
            println!("(skipping {registered} clients; raise FEDAE_BENCH_MAX_CLIENTS)");
            continue;
        }
        let tier = run_tier(&rt, registered, 2)?;
        if baseline_ms.is_none() {
            baseline_ms = Some(tier.per_round_ms);
        }
        top_tier_ms = Some(tier.per_round_ms);
        rows.push(vec![
            registered.to_string(),
            format!("{:.0}", tier.per_round_ms),
            tier.activated.to_string(),
            tier.resident_peak.to_string(),
        ]);
    }
    println!(
        "{}",
        print_table(
            &["registered", "ms/round", "activations", "peak resident"],
            &rows
        )
    );

    // The acceptance assert: per-round cost is a function of K (active),
    // not N (registered). Selection is O(K) and state is O(resident), so
    // the largest tier must land within noise of the smallest.
    if let (Some(base), Some(top)) = (baseline_ms, top_tier_ms) {
        assert!(
            top < 5.0 * base.max(1.0),
            "round time grew with registered population: {base:.0}ms -> {top:.0}ms"
        );
        println!("(round time ~flat in registered population: {base:.0}ms -> {top:.0}ms)");
    }
    Ok(())
}
