//! Bench — L3 coordinator overhead: aggregation algorithms and the round
//! state machine at increasing collaborator counts, isolated from PJRT
//! compute (synthetic updates). The coordinator must not be the
//! bottleneck (EXPERIMENTS.md §Perf): these paths are O(C·n) single-pass.
//!
//! `cargo bench --bench bench_coordinator`

use fedae::aggregation::{self, WeightedUpdate};
use fedae::compression::CompressedUpdate;
use fedae::config::AggregationConfig;
use fedae::coordinator::RoundState;
use fedae::metrics::print_table;
use fedae::util::bench_timings;
use fedae::util::rng::Rng;

fn main() -> fedae::error::Result<()> {
    println!("== L3 coordinator micro-benchmarks (no PJRT) ==");
    let n = 51_082; // CIFAR-shaped update
    let mut rng = Rng::new(3);

    // Aggregation scaling over collaborators.
    let mut rows = Vec::new();
    for &collabs in &[2usize, 8, 32, 128] {
        let updates: Vec<WeightedUpdate> = (0..collabs)
            .map(|_| WeightedUpdate {
                weight: 1.0 + rng.uniform() * 100.0,
                values: (0..n).map(|_| rng.normal_f32(0.0, 0.05)).collect(),
            })
            .collect();
        for cfg in [
            AggregationConfig::Mean,
            AggregationConfig::FedAvg,
            AggregationConfig::Median,
            AggregationConfig::TrimmedMean { trim: 0.1 },
        ] {
            let mut agg = aggregation::from_config(&cfg)?;
            let iters = if matches!(cfg, AggregationConfig::Median | AggregationConfig::TrimmedMean { .. })
                && collabs >= 32
            {
                3
            } else {
                10
            };
            let (mean, p50, _) = bench_timings(1, iters, || {
                let _ = agg.aggregate(&updates).unwrap();
            });
            rows.push(vec![
                agg.name().to_string(),
                collabs.to_string(),
                format!("{mean:.2}"),
                format!("{p50:.2}"),
                format!("{:.1}", (collabs * n) as f64 / mean / 1e3), // Melem/s
            ]);
        }
    }
    println!(
        "{}",
        print_table(
            &["aggregator", "collabs", "mean ms", "p50 ms", "Melem/s"],
            &rows
        )
    );

    // Round state machine throughput.
    let mut rows = Vec::new();
    for &collabs in &[10usize, 100, 1000] {
        let payload = CompressedUpdate::Latent {
            z: vec![0.0; 32],
            n: n as u32,
        };
        let (mean, _, _) = bench_timings(1, 20, || {
            let mut state = RoundState::new(0, 0..collabs);
            for c in 0..collabs {
                state.accept(0, c, 100, payload.clone()).unwrap();
            }
            assert!(state.is_complete());
        });
        rows.push(vec![
            collabs.to_string(),
            format!("{mean:.3}"),
            format!("{:.0}", collabs as f64 / mean * 1000.0),
        ]);
    }
    println!(
        "{}",
        print_table(&["collabs", "round accept ms", "updates/s"], &rows)
    );
    Ok(())
}
