//! Bench — end-to-end federated-round latency (the system's "request
//! path"): broadcast -> local train -> encode -> decode -> aggregate ->
//! eval, per compression scheme. Complements bench_coordinator (which
//! isolates L3) by timing the whole stack including PJRT compute.
//!
//! `cargo bench --bench bench_fl_round`

use fedae::config::{CompressionConfig, ExperimentConfig};
use fedae::coordinator::FlDriver;
use fedae::metrics::print_table;
use fedae::runtime::{AePipeline, Runtime};
use fedae::util::Stopwatch;

fn main() -> fedae::error::Result<()> {
    // Runs on the native backend from a clean checkout; compiled XLA
    // artifacts are used automatically when present (--features xla).
    let rt = Runtime::from_dir("artifacts")?;
    let pipeline = AePipeline::new(&rt, "mnist")?;
    println!("== end-to-end round latency, 2 collaborators, synth-mnist ==");

    let mut rows = Vec::new();
    for (label, compression) in [
        ("identity", CompressionConfig::Identity),
        ("ae", CompressionConfig::Ae { ae: "mnist".into() }),
        ("topk 1%", CompressionConfig::TopK { fraction: 0.01 }),
        (
            "quantize 8b",
            CompressionConfig::Quantize { bits: 8, stochastic: false },
        ),
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "mnist".into();
        cfg.compression = compression.clone();
        cfg.fl.collaborators = 2;
        cfg.fl.rounds = 64; // driver cap; we time 8 rounds below
        cfg.fl.local_epochs = 1;
        cfg.data.per_collab = 256;
        cfg.data.test_size = 256;
        cfg.prepass.epochs = 6;
        cfg.prepass.ae_epochs = 4;
        cfg.seed = 5;
        let pipe_ref =
            matches!(cfg.compression, CompressionConfig::Ae { .. }).then_some(&pipeline);

        let setup = Stopwatch::start();
        let mut builder = FlDriver::builder(&rt, cfg);
        if let Some(p) = pipe_ref {
            builder = builder.pipeline(p);
        }
        let mut driver = builder.build()?;
        let setup_s = setup.elapsed_secs();

        driver.run_round()?; // warm the executable cache
        let sw = Stopwatch::start();
        let rounds = 8;
        for _ in 0..rounds {
            driver.run_round()?;
        }
        let per_round_ms = sw.elapsed_ms() / rounds as f64;
        rows.push(vec![
            label.to_string(),
            format!("{setup_s:.2}s"),
            format!("{per_round_ms:.1}"),
            format!("{:.1}", 1000.0 / per_round_ms),
        ]);
    }
    println!(
        "{}",
        print_table(
            &["compression", "setup (incl. prepass)", "round ms", "rounds/s"],
            &rows
        )
    );
    println!("(setup for `ae` includes the pre-pass: classifier + AE training per collaborator)");
    Ok(())
}
