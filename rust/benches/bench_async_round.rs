//! Bench — sync barrier vs deadline-driven async rounds at large
//! federation sizes (ISSUE 3 acceptance: async rounds complete with a
//! bounded deadline while reporting dropped/stale update counts at
//! 64/256/1024 collaborators).
//!
//! Per federation size this runs the same fixed-seed experiment three
//! ways over a heterogeneous (lognormal-slowdown + jitter + dropout)
//! client population:
//!
//! * **sync** — the paper's full barrier (no straggler model; the
//!   reference for host wall-clock and bytes),
//! * **async / infinite deadline** — stragglers modelled, every arrival
//!   admitted: the *simulated* round time is gated by the slowest client,
//! * **async / bounded deadline** — rounds close at the deadline; late
//!   updates buffer and fold in staleness-discounted next rounds.
//!
//! It asserts the degenerate async configuration matches sync bitwise,
//! and that every bounded-deadline round's simulated duration is capped
//! by the deadline, then reports host ms/round, simulated s/round, bytes
//! on the wire, and admitted/late/dropped/stale counts.
//!
//! `cargo bench --bench bench_async_round`
//! (set `FEDAE_BENCH_MAX_COLLABS=1024` for the largest tier; default 256.)

use fedae::config::{CompressionConfig, EngineConfig, EngineMode, ExperimentConfig};
use fedae::coordinator::{FlDriver, RoundOutcome, StragglerStats};
use fedae::metrics::print_table;
use fedae::runtime::Runtime;
use fedae::util::Stopwatch;

/// Bounded deadline in simulated ms: the raw mnist update takes ~25 ms
/// on the default 100 Mbps / 20 ms link, so a 40 ms deadline admits the
/// median client and cuts the lognormal tail.
const DEADLINE_MS: f64 = 40.0;

fn engine(mode: EngineMode, deadline_ms: f64) -> EngineConfig {
    let straggler = mode == EngineMode::Async;
    EngineConfig {
        parallelism: 0,
        shard_size: 0,
        mode,
        deadline_ms,
        staleness_decay: 1.0,
        dropout_rate: if straggler { 0.02 } else { 0.0 },
        straggler_log_std: if straggler { 0.6 } else { 0.0 },
        jitter_ms: if straggler { 10.0 } else { 0.0 },
    }
}

fn cfg_for(collabs: usize, engine: EngineConfig) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("bench_async_round_{collabs}");
    cfg.model = "mnist".into();
    // Identity compression: no pre-pass, so setup stays cheap at 1024
    // collaborators and the timing isolates the round path.
    cfg.compression = CompressionConfig::Identity;
    cfg.fl.collaborators = collabs;
    cfg.fl.rounds = 8; // driver cap; we time fewer below
    cfg.fl.local_epochs = 1;
    cfg.data.per_collab = 64;
    cfg.data.test_size = 128;
    cfg.seed = 17;
    cfg.engine = engine;
    cfg
}

struct BenchRun {
    per_round_ms: f64,
    outcomes: Vec<RoundOutcome>,
    global: Vec<f32>,
    totals: StragglerStats,
    pending: usize,
    bytes_up: u64,
}

fn timed_rounds(
    rt: &Runtime,
    collabs: usize,
    engine: EngineConfig,
    rounds: usize,
) -> fedae::error::Result<BenchRun> {
    let mut driver = FlDriver::builder(rt, cfg_for(collabs, engine)).build()?;
    let sw = Stopwatch::start();
    let mut outcomes = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        outcomes.push(driver.run_round()?);
    }
    let per_round_ms = sw.elapsed_ms() / rounds as f64;
    let totals = driver.async_totals().unwrap_or_else(|| {
        // Sync mode: fold the per-round stats by hand for the report.
        let mut t = StragglerStats::default();
        for o in &outcomes {
            t.admitted += o.stragglers.admitted;
            t.sim_round_seconds += o.stragglers.sim_round_seconds;
        }
        t
    });
    Ok(BenchRun {
        per_round_ms,
        pending: driver.async_pending(),
        bytes_up: driver.network.ledger().update_bytes_up(),
        global: driver.global_params().to_vec(),
        outcomes,
        totals,
    })
}

fn main() -> fedae::error::Result<()> {
    let rt = Runtime::from_dir("artifacts")?;
    let max_collabs: usize = std::env::var("FEDAE_BENCH_MAX_COLLABS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    println!("== sync barrier vs deadline-driven async rounds, synth-mnist ==");

    // Degenerate-async sanity: with every straggler knob zero and an
    // infinite deadline, async must reproduce sync bitwise.
    {
        let sync = timed_rounds(&rt, 16, engine(EngineMode::Sync, 0.0), 2)?;
        let degenerate = timed_rounds(&rt, 16, engine(EngineMode::Async, 0.0), 2)?;
        assert_eq!(sync.outcomes, degenerate.outcomes, "degenerate async diverged");
        assert_eq!(sync.global, degenerate.global, "degenerate async params diverged");
    }

    let mut rows = Vec::new();
    for collabs in [64, 256, 1024] {
        if collabs > max_collabs {
            println!("(skipping {collabs} collaborators; raise FEDAE_BENCH_MAX_COLLABS)");
            continue;
        }
        let rounds = if collabs >= 1024 { 2 } else { 3 };
        for (label, eng) in [
            ("sync", engine(EngineMode::Sync, 0.0)),
            ("async-inf", engine(EngineMode::Async, 0.0)),
            ("async-deadline", engine(EngineMode::Async, DEADLINE_MS)),
        ] {
            let run = timed_rounds(&rt, collabs, eng, rounds)?;
            // The acceptance property: a bounded deadline bounds every
            // round's simulated duration.
            if label == "async-deadline" {
                for o in &run.outcomes {
                    assert!(
                        o.stragglers.sim_round_seconds <= DEADLINE_MS * 1e-3 + 1e-12,
                        "round {} overran the deadline: {} s",
                        o.round,
                        o.stragglers.sim_round_seconds
                    );
                }
            }
            let t = run.totals;
            rows.push(vec![
                collabs.to_string(),
                label.to_string(),
                format!("{:.0}", run.per_round_ms),
                format!("{:.4}", t.sim_round_seconds / rounds as f64),
                t.admitted.to_string(),
                t.late.to_string(),
                t.dropped.to_string(),
                format!("{}({})", t.stale_applied, run.pending),
                run.bytes_up.to_string(),
            ]);
        }
    }
    println!(
        "{}",
        print_table(
            &[
                "collaborators",
                "engine",
                "host ms/round",
                "sim s/round",
                "admitted",
                "late",
                "dropped",
                "stale(pend)",
                "update bytes up"
            ],
            &rows
        )
    );
    println!(
        "(async-inf sim time is gated by the slowest modelled client; \
         async-deadline rounds are capped at {DEADLINE_MS} ms simulated, \
         trading admitted-update count for bounded round time)"
    );
    Ok(())
}
