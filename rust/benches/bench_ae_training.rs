//! Bench E1/E3 support — AE training-step throughput (the pre-pass cost
//! the paper's §4.3 worries about: "computational overhead while training
//! this network").
//!
//! Times one Adam step of each exported AE (PJRT-compiled XLA, Pallas
//! fused-dense inside) and reports steps/s plus the projected pre-pass
//! wall-clock for the paper's schedules.
//!
//! `cargo bench --bench bench_ae_training`

use fedae::metrics::print_table;
use fedae::runtime::{AdamState, AePipeline, Runtime};
use fedae::util::bench_timings;

fn main() -> fedae::error::Result<()> {
    // Runs on the native backend from a clean checkout; compiled XLA
    // artifacts are used automatically when present (--features xla).
    let rt = Runtime::from_dir("artifacts")?;
    println!("== AE train-step throughput (pre-pass cost model) ==");

    let mut rows = Vec::new();
    for tag in ["mnist", "cifar", "mnist_deep"] {
        let pipeline = AePipeline::new(&rt, tag)?;
        let mut ae = rt.load_init(&format!("ae_{tag}_init"))?;
        let mut adam = AdamState::zeros(ae.len());
        // Synthetic weights batch (values in the weight-scale regime).
        let batch: Vec<f32> = (0..pipeline.train_batch * pipeline.input_dim)
            .map(|i| ((i as f32 * 0.37).sin()) * 0.05)
            .collect();
        let (mean, p50, p95) = bench_timings(3, 15, || {
            let _ = pipeline.train_step(&mut ae, &mut adam, &batch).unwrap();
        });
        // Paper-style schedule: 40 snapshots, batch b, 30 epochs.
        let steps = (40usize.div_ceil(pipeline.train_batch)) * 30;
        rows.push(vec![
            tag.to_string(),
            pipeline.n_params.to_string(),
            pipeline.train_batch.to_string(),
            format!("{mean:.1} / {p50:.1} / {p95:.1}"),
            format!("{:.1}", 1000.0 / mean),
            format!("{:.1}s", steps as f64 * mean / 1000.0),
        ]);
    }
    println!(
        "{}",
        print_table(
            &[
                "ae",
                "params",
                "batch",
                "step ms (mean/p50/p95)",
                "steps/s",
                "prepass(40x30)",
            ],
            &rows
        )
    );
    Ok(())
}
